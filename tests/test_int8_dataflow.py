"""End-to-end int8 dataflow: producer-side activation emission.

The contract under test: ``plan_program``'s producer->consumer pass
assigns ``Epilogue`` descriptors so every fused int8 consumer receives
int8 activations emitted by its producer (in-kernel for the Pallas
megakernels, XLA-fused for structural convs), residual adds stay fp,
and the executed chain remains BIT-EXACT vs the int8 reference at
batch 1 — the quantize arithmetic moved across the producer/consumer
boundary, it did not change.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.efficientvit import (
    B1, B1_SMOKE, init_dsconv, init_efficientvit, init_mbconv)
from repro.core.fusion import plan_program, plan_report
from repro.core.program import Epilogue, Program, Site, execute, lower
from repro.core.quantization import (
    QTensor, quantize_act, quantize_efficientvit, quantize_tensor)
from repro.kernels import registry


def _qtree(seed, cfg=B1_SMOKE):
    return quantize_efficientvit(
        init_efficientvit(jax.random.PRNGKey(seed), cfg))


# ---------------------------------------------------------------------------
# epilogue assignment: structure at serving resolutions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("res", [192, 224, 256])
@pytest.mark.parametrize("batch", [1, 4])
def test_b1_epilogue_assignment(res, batch, tmp_autotune_cache):
    """At every serving resolution/bucket the full B1 chain is covered:
    every fused int8 site's input arrives quantized, every producer's
    residual policy matches the (producer, consumer) residual pair."""
    qparams = _qtree(0, B1)
    program = lower(B1, batch=batch, image_size=res)
    plan = plan_program(program, qparams, autotune=False)
    assert all(d.fused and d.precision == "int8"
               for d in plan.decisions.values())
    assert all(d.q_in for d in plan.decisions.values())
    by_name = {s.name: s for s in program.sites}
    consumer = {prv.name: cur for prv, cur in
                zip(program.sites, program.sites[1:])}
    # the structural quantized stem conv and head conv take part too
    assert "stem.conv1" in plan.epilogues
    for name, ep in plan.epilogues.items():
        site = by_name[name]
        assert ep.out_dtype == "int8" and ep.scale == "dynamic"
        if site.residual:
            assert ep.residual == "post-add", name
        elif consumer[name].residual:
            assert ep.residual == "keep-fp", name
        else:
            assert ep.residual == "none", name
    # annotated program mirrors the plan (the executor-cache view)
    annotated = program.with_epilogues(plan)
    for s in annotated.sites:
        assert s.epilogue == plan.epilogues.get(s.name, s.epilogue) \
            or not s.epilogue.emits_q


def test_fp_plan_assigns_no_epilogues(tmp_autotune_cache):
    params = init_efficientvit(jax.random.PRNGKey(1), B1_SMOKE)
    plan = plan_program(lower(B1_SMOKE), params, autotune=False)
    assert plan.epilogues == {}
    assert not any(d.q_in for d in plan.decisions.values())


def test_epilogues_opt_out(tmp_autotune_cache):
    """plan_program(..., epilogues=False) keeps the legacy consumer-side
    quantize dataflow — and matches the epilogue chain bit-for-bit at
    batch 1 (the arithmetic only moved across the boundary)."""
    qparams = _qtree(2)
    program = lower(B1_SMOKE, batch=1, image_size=64)
    on = plan_program(program, qparams, autotune=False)
    off = plan_program(program, qparams, autotune=False, epilogues=False)
    assert on.epilogues and not off.epilogues
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 64, 3))
    np.testing.assert_array_equal(
        np.asarray(execute(program, qparams, x, plan=on)),
        np.asarray(execute(program, qparams, x, plan=off)))


# ---------------------------------------------------------------------------
# producer-epilogue kernel parity vs the XLA-quantize reference
# ---------------------------------------------------------------------------

def test_mbconv_emit_matches_xla_quantize():
    """In-kernel emission == running the non-emitting kernel and
    quantizing its output in XLA, bit for bit (both keep-fp and pure)."""
    from repro.kernels.mbconv.ops import mbconv_apply_int8
    key = jax.random.PRNGKey(4)
    qp = quantize_efficientvit(init_mbconv(key, 8, 16, 4, jnp.float32))
    for stride in (1, 2):
        x = jax.random.normal(jax.random.fold_in(key, stride),
                              (2, 16, 16, 8))
        base = mbconv_apply_int8(qp, x, stride=stride, block_f=128)
        want = quantize_act(base)
        for residual in ("none", "keep-fp"):
            got = mbconv_apply_int8(
                qp, x, stride=stride,
                epilogue=Epilogue("int8", "dynamic", residual))
            assert isinstance(got, QTensor)
            np.testing.assert_array_equal(np.asarray(got.q),
                                          np.asarray(want.q))
            # scales may differ by FMA-fusion ulps between compilation
            # contexts (per-batch-element scale arithmetic reassociates)
            assert_allclose(np.asarray(got.scale), np.asarray(want.scale),
                            rtol=1e-6, atol=0)
            if residual == "keep-fp":   # fp preserved for the consumer's
                np.testing.assert_array_equal(   # residual add
                    np.asarray(got.fp), np.asarray(base))
            else:
                assert got.fp is None


def test_dsconv_emit_matches_xla_quantize():
    from repro.kernels.dsconv.ops import dsconv_apply_int8
    key = jax.random.PRNGKey(5)
    qp = quantize_efficientvit(init_dsconv(key, 8, 8, jnp.float32))
    x = jax.random.normal(key, (2, 12, 12, 8))
    base = dsconv_apply_int8(qp, x)
    want = quantize_act(base)
    got = dsconv_apply_int8(qp, x,
                            epilogue=Epilogue("int8", "dynamic", "none"))
    np.testing.assert_array_equal(np.asarray(got.q), np.asarray(want.q))
    # scales may differ by FMA-fusion ulps between compilation contexts
    assert_allclose(np.asarray(got.scale), np.asarray(want.scale),
                    rtol=1e-6, atol=0)


def test_dsconv_consumes_qtensor_bit_exact():
    """A producer-emitted QTensor input reproduces the fp-input path
    exactly at batch 1 (same absmax arithmetic, just moved)."""
    from repro.kernels.dsconv.ops import dsconv_apply_int8
    key = jax.random.PRNGKey(6)
    qp = quantize_efficientvit(init_dsconv(key, 8, 8, jnp.float32))
    x = jax.random.normal(key, (1, 12, 12, 8))
    via_fp = dsconv_apply_int8(qp, x)
    via_qt = dsconv_apply_int8(qp, quantize_act(x))
    np.testing.assert_array_equal(np.asarray(via_fp), np.asarray(via_qt))


def test_conv1x1_w8a8_emit_and_qtensor():
    from repro.core.quantization import conv2d_int8
    from repro.kernels.int8_matmul.ops import conv1x1_w8a8
    rng = np.random.default_rng(7)
    B, H, W, C, F = 2, 6, 6, 16, 32
    x = jnp.asarray(rng.standard_normal((B, H, W, C)), jnp.float32)
    qp = {"q": jnp.asarray(rng.integers(-127, 128, (1, 1, C, F)), jnp.int8),
          "scale": jnp.asarray(rng.uniform(0.005, 0.05, (F,)), jnp.float32),
          "bias": jnp.asarray(rng.standard_normal((F,)), jnp.float32)}
    base = conv1x1_w8a8(qp, x)
    # in-kernel emission == XLA quantize of the same fp output
    want = quantize_act(base)
    got = conv1x1_w8a8(qp, x, epilogue=Epilogue("int8", "dynamic", "none"))
    np.testing.assert_array_equal(np.asarray(got.q), np.asarray(want.q))
    assert_allclose(np.asarray(got.scale), np.asarray(want.scale),
                    rtol=1e-6, atol=0)
    # QTensor input at batch 1: same int8 values into the GEMM as the
    # conv2d_int8 reference quantize — dequant-epilogue ulps only (the
    # same 1e-5 window the pre-epilogue conv1x1 parity test uses)
    x1 = x[:1]
    ref = conv2d_int8(qp, x1)
    out = conv1x1_w8a8(qp, quantize_act(x1))
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_group_agg_matches_reference_chain():
    """The grouped int8 aggregation kernel == the reference
    conv2d_int8(dw) -> conv2d_int8(pw) chain, bit-exact at batch 1."""
    from repro.core.quantization import conv2d_int8
    from repro.core.relu_attention import MSAConfig, init_msa
    from repro.kernels.group_conv.ops import group_agg_apply_int8
    key = jax.random.PRNGKey(8)
    cfg = MSAConfig(32, head_dim=16, scales=(5,))
    qmsa = quantize_efficientvit(init_msa(key, cfg))
    agg = qmsa["aggreg"][0]
    C = 3 * cfg.total_dim
    qkv = jax.random.normal(key, (1, 8, 8, C))
    ref = conv2d_int8(agg["dw"]["qconv"], qkv, groups=C)
    ref = conv2d_int8(agg["pw"]["qconv"], ref, groups=3 * cfg.n_heads)
    out = group_agg_apply_int8(agg, qkv)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # registry face: int8-only kind, apply == wrapper, ref == chain
    impl = registry.get_kernel("group_agg", "int8")
    assert impl.takes_q and impl.site_precision(agg) == "int8"
    site = Site("X.agg", "group_agg", "X", (), qkv.shape, qkv.shape,
                attrs={"scale": 5})
    np.testing.assert_array_equal(np.asarray(impl.apply(agg, qkv, site)),
                                  np.asarray(out))
    np.testing.assert_array_equal(np.asarray(impl.ref(agg, qkv, site)),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# the chain: fused-with-epilogues vs the int8 reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("res,batch", [(32, 1), (32, 4), (64, 1), (96, 2)])
def test_int8_chain_parity_across_buckets(res, batch, tmp_autotune_cache):
    """Producer-epilogue chain vs the XLA-quantize reference across the
    serving (resolution, batch-bucket) grid: identical int8 arithmetic
    at batch 1 (same quantize decisions at every boundary; the logits
    may carry dequant-epilogue FMA ulps, and the pinned
    benchmarks/e2e_latency configuration is literally bit-exact),
    within quantization noise (top-1 preserved) otherwise."""
    qparams = _qtree(9)
    program = lower(B1_SMOKE, batch=batch, image_size=res)
    plan = plan_program(program, qparams, autotune=False)
    assert plan.epilogues, "no epilogues assigned"
    x = jax.random.normal(jax.random.PRNGKey(res + batch),
                          (batch, res, res, 3))
    ref = execute(program, qparams, x)
    fus = execute(program, qparams, x, plan=plan)
    assert bool((jnp.argmax(ref, -1) == jnp.argmax(fus, -1)).all())
    if batch == 1:
        assert_allclose(np.asarray(fus), np.asarray(ref),
                        rtol=1e-5, atol=1e-7)
    else:
        assert float(jnp.max(jnp.abs(ref - fus))) < 1e-2


def test_residual_adds_stay_fp(tmp_autotune_cache):
    """A residual consumer's add must see the producer's fp activation,
    never a dequantized int8 round-trip: the keep-fp boundaries exist in
    the plan, stripping one to a pure-int8 epilogue trips the fp guard
    (``act_fp``) instead of silently degrading, and the chain with the
    assigned plan stays bit-exact vs the all-fp-residual reference."""
    import dataclasses as dc
    qparams = _qtree(10)
    program = lower(B1_SMOKE, batch=1, image_size=64)
    plan = plan_program(program, qparams, autotune=False)
    keep_fp_sites = [n for n, ep in plan.epilogues.items()
                     if ep.residual == "keep-fp"]
    assert keep_fp_sites, "no keep-fp boundaries in the chain"
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 64, 64, 3))
    ref = execute(program, qparams, x)    # residual adds all run fp here
    np.testing.assert_array_equal(
        np.asarray(execute(program, qparams, x, plan=plan)),
        np.asarray(ref))
    # a mis-assigned pure-int8 boundary in front of a residual consumer
    # must fail loudly (epilogue-assignment invariant), not approximate
    lossy_eps = dict(plan.epilogues)
    lossy_eps[keep_fp_sites[0]] = Epilogue("int8", "dynamic", "none")
    lossy = dc.replace(plan, epilogues=lossy_eps)
    with pytest.raises(ValueError, match="kept fp activation"):
        execute(program, qparams, x, plan=lossy)


def test_quantize_act_contract():
    """Per-batch-element scales == quantize_tensor at batch 1; keep_fp
    carries the exact input."""
    x = jax.random.normal(jax.random.PRNGKey(12), (3, 5, 5, 4))
    qt = quantize_act(x, keep_fp=True)
    assert qt.q.dtype == jnp.int8 and qt.scale.shape == (3,)
    assert qt.fp is x
    q1, s1 = quantize_tensor(x[:1])
    np.testing.assert_array_equal(np.asarray(qt.q[:1]), np.asarray(q1))
    assert float(qt.scale[0]) == float(s1)
    assert quantize_act(x).fp is None


# ---------------------------------------------------------------------------
# plan reuse: exact-batch donors for batch-dependent tile families
# ---------------------------------------------------------------------------

def test_reuse_exact_batch_for_batch_dependent_tiles(tmp_autotune_cache):
    """A kernel family that tunes batch-dependent tiles only inherits
    donor blocks from the SAME batch; per-sample-geometry matching
    (the default) keeps sharing across buckets."""

    class _Base(registry.KernelBase):
        kind, precision, dtype = "unit_bdt", "fp", "f32"

        def site_precision(self, params):
            return "fp"

        def tune(self, site, *, autotune=True, interpret=None):
            return {"block": site.in_shape[0]}    # batch-dependent!

        def apply(self, params, x, site, decision=None, *, interpret=None,
                  epilogue=None):
            return x

    def _program(batch):
        site = Site("X.bdt0", "unit_bdt", "X", (),
                    (batch, 4, 4, 8), (batch, 4, 4, 8))
        return Program(B1_SMOKE, batch, 4, (site,))

    try:
        registry.register(type("BDT", (_Base,),
                               {"batch_dependent_tiles": True}))
        donor = plan_program(_program(4), {}, autotune=False)
        assert donor.get("X.bdt0").blocks == {"block": 4}
        # different batch: no safe donor -> re-tuned, not reused
        other = plan_program(_program(2), {}, autotune=False, reuse=donor)
        d = other.get("X.bdt0")
        assert not d.reused and d.blocks == {"block": 2}
        # exact batch: donor accepted
        same = plan_program(_program(4), {}, autotune=False, reuse=donor)
        assert same.get("X.bdt0").reused
        # default (per-sample-geometry) families still share across batch
        registry.register(type("NBDT", (_Base,), {}))
        donor2 = plan_program(_program(4), {}, autotune=False)
        shared = plan_program(_program(2), {}, autotune=False, reuse=donor2)
        assert shared.get("X.bdt0").reused
    finally:
        registry.unregister("unit_bdt", "fp")


# ---------------------------------------------------------------------------
# serving: the quantized engine runs the int8 dataflow
# ---------------------------------------------------------------------------

def test_vision_engine_quantized_epilogue_dataflow(tmp_autotune_cache):
    from repro.core.efficientvit import efficientvit
    from repro.serving.vision import VisionEngine, VisionServeConfig
    key = jax.random.PRNGKey(13)
    params = init_efficientvit(key, B1_SMOKE)
    eng = VisionEngine.quantized(
        params, B1_SMOKE, VisionServeConfig(microbatch=2, autotune=False))
    # the compiled executors carry the epilogue mode in their cache key,
    # and the cached (annotated) program exposes the delivered dtypes
    assert all(k.epilogues for k in eng.cache.keys())
    assert any(s.epilogue.emits_q for s in eng.program.sites)
    imgs = jax.random.normal(key, (3, 64, 64, 3))
    logits = eng.logits(imgs)
    ref = jnp.concatenate(
        [efficientvit(eng.params, imgs[i:i + 1], B1_SMOKE)
         for i in range(3)])
    # the ragged tail runs a 1-bucket: that sample is the batch-1
    # producer-epilogue chain vs its per-sample reference (dequant ulps)
    assert_allclose(np.asarray(logits[2:]), np.asarray(ref[2:]),
                    rtol=1e-5, atol=1e-7)
    assert_allclose(np.asarray(logits), np.asarray(ref),
                    rtol=1e-4, atol=1e-4)
    # legacy dataflow stays available as an A/B lever, same answers
    eng_off = VisionEngine.quantized(
        params, B1_SMOKE, VisionServeConfig(microbatch=2, autotune=False,
                                            epilogues=False))
    assert not any(k.epilogues for k in eng_off.cache.keys())
    assert_allclose(np.asarray(logits[2:]),
                    np.asarray(eng_off.logits(imgs)[2:]),
                    rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# accounting: delivered bytes and the cycle model's residual-fp charge
# ---------------------------------------------------------------------------

def test_delivered_bytes_match_analytic_within_residual_fp(
        tmp_autotune_cache):
    """Per fused int8 conv site: delivered == analytic steady-state
    + outn (residual-fp correction) when the epilogue keeps fp,
    - 3*outn when the boundary is pure int8, both sides exact."""
    qparams = _qtree(14)
    program = lower(B1_SMOKE, batch=1, image_size=64)
    plan = plan_program(program, qparams, autotune=False)
    for r in plan_report(plan):
        if not (r["fused"] and r["kind"] in ("mbconv", "dsconv")):
            continue
        assert r["q_in"], r["site"]
        B, H, W, C, _, F, stride = plan.get(r["site"]).shape
        outn = (B * (H // stride) * (W // stride) * F
                if r["kind"] == "mbconv" else B * H * W * F)
        ep = r["epilogue"]
        if ep is None or not ep.emits_q:
            corr = 0
        elif ep.keeps_fp:
            corr = outn          # fp copy + int8 copy cross the boundary
        else:
            corr = -3 * outn     # pure 1 byte/element boundary
        assert r["hbm_delivered"] == r["hbm_fused"] + corr, r["site"]


def test_cycle_model_charges_residual_fp(tmp_autotune_cache):
    from repro.core.accelerator_model import analyze_program
    qparams = _qtree(15, B1)
    program = lower(B1, batch=1)
    plan = plan_program(program, qparams, autotune=False)
    plain, _, _ = analyze_program(program)
    annotated, _, _ = analyze_program(program.with_epilogues(plan))
    assert annotated.dram_bytes >= plain.dram_bytes
    assert annotated.total_macs == plain.total_macs
