"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle.

Every kernel is validated against its pure-jnp oracle across randomized
shapes and dtypes via the seeded sweep harness (tests/proptest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from proptest import sweep

from repro.kernels.dsconv.kernel import dsconv_fused
from repro.kernels.dsconv.ref import dsconv_ref
from repro.kernels.int8_matmul.kernel import int8_matmul
from repro.kernels.relu_attn.kernel import relu_attn_causal, relu_attn_noncausal
from repro.kernels.relu_attn.ops import relu_linear_attention
from repro.kernels.relu_attn.ref import relu_attn_causal_ref, relu_attn_noncausal_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_recurrent_ref

TOLS = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _qkv(rng, b, n, d, dtype):
    def one(seed):
        return jnp.asarray(rng.standard_normal((b, n, d)), dtype)

    return one(0), one(1), one(2)


# ---------------------------------------------------------------------------
# relu_attn
# ---------------------------------------------------------------------------

@sweep(n_cases=8, seed=1)
def test_relu_attn_noncausal_sweep(rng):
    dtype = [jnp.float32, jnp.bfloat16][int(rng.integers(2))]
    b = int(rng.integers(1, 5))
    n = int(rng.integers(1, 9)) * 16
    d = int(rng.choice([16, 32, 64]))
    block = int(rng.choice([16, 32, n]))
    q, k, v = _qkv(rng, b, n, d, dtype)
    out = relu_attn_noncausal(q, k, v, block_n=block)
    ref = relu_attn_noncausal_ref(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(ref), **TOLS[dtype])


@sweep(n_cases=8, seed=2)
def test_relu_attn_causal_sweep(rng):
    dtype = [jnp.float32, jnp.bfloat16][int(rng.integers(2))]
    b = int(rng.integers(1, 4))
    n = int(rng.integers(1, 9)) * 16
    d = int(rng.choice([16, 32]))
    chunk = int(rng.choice([16, 32, n]))
    q, k, v = _qkv(rng, b, n, d, dtype)
    out = relu_attn_causal(q, k, v, chunk=chunk)
    ref = relu_attn_causal_ref(q, k, v)
    assert_allclose(np.asarray(out), np.asarray(ref), **TOLS[dtype])


def test_relu_attn_ops_multihead():
    key = jax.random.PRNGKey(0)
    B, N, H, D = 2, 64, 4, 32
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, N, H, D))
               for i in range(3))
    out = relu_linear_attention(q, k, v, causal=False)
    # oracle per head
    for h in range(H):
        ref = relu_attn_noncausal_ref(q[:, :, h], k[:, :, h], v[:, :, h])
        assert_allclose(np.asarray(out[:, :, h]), np.asarray(ref),
                        rtol=2e-5, atol=2e-5)


def test_relu_attn_linearity_in_v():
    """Linear attention must be exactly linear in V (paper's associativity)."""
    key = jax.random.PRNGKey(3)
    q, k, v1, v2 = (jax.random.normal(jax.random.fold_in(key, i), (2, 32, 16))
                    for i in range(4))
    a = relu_attn_noncausal(q, k, v1 + 2.0 * v2)
    b = relu_attn_noncausal(q, k, v1) + 2.0 * relu_attn_noncausal(q, k, v2)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dsconv (TMP inter-layer fusion)
# ---------------------------------------------------------------------------

@sweep(n_cases=8, seed=3)
def test_dsconv_sweep(rng):
    b = int(rng.integers(1, 3))
    hw = int(rng.choice([8, 12, 16]))
    c = int(rng.choice([8, 16, 32]))
    f = int(rng.choice([16, 32, 64]))
    stride = int(rng.choice([1, 2]))
    act = bool(rng.integers(2))
    x = jnp.asarray(rng.standard_normal((b, hw, hw, c)), jnp.float32)
    dw_w = jnp.asarray(rng.standard_normal((3, 3, c)), jnp.float32)
    dw_b = jnp.asarray(rng.standard_normal((c,)), jnp.float32)
    pw_w = jnp.asarray(rng.standard_normal((c, f)), jnp.float32)
    pw_b = jnp.asarray(rng.standard_normal((f,)), jnp.float32)
    out = dsconv_fused(x, dw_w, dw_b, pw_w, pw_b, stride=stride, act=act)
    ref = dsconv_ref(x, dw_w, dw_b, pw_w, pw_b, stride=stride, act=act)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_dsconv_matches_lax_conv():
    """Cross-check the oracle itself against lax.conv depthwise+pointwise."""
    from repro.layers.conv import conv2d
    key = jax.random.PRNGKey(1)
    b, hw, c, f = 2, 8, 8, 16
    x = jax.random.normal(key, (b, hw, hw, c))
    dw_w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, c))
    pw_w = jax.random.normal(jax.random.fold_in(key, 2), (c, f))
    out = dsconv_ref(x, dw_w, jnp.zeros((c,)), pw_w, jnp.zeros((f,)),
                     act=False)
    dw = conv2d({"w": dw_w[:, :, None, :]}, x, groups=c)
    ref = jnp.einsum("bhwc,cf->bhwf", dw, pw_w)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# int8 matmul (FIX8 datapath)
# ---------------------------------------------------------------------------

@sweep(n_cases=8, seed=4)
def test_int8_matmul_sweep(rng):
    m = int(rng.choice([16, 32, 64]))
    k = int(rng.choice([32, 64, 128]))
    n = int(rng.choice([16, 48, 96]))
    bm = int(rng.choice([16, m]))
    xq = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    xs = float(rng.uniform(0.01, 0.2))
    ws = jnp.asarray(rng.uniform(0.01, 0.2, (n,)), jnp.float32)
    out = int8_matmul(xq, wq, xs, ws, block_m=bm, block_n=16, block_k=32)
    ref = (xq.astype(jnp.int32) @ wq.astype(jnp.int32)).astype(jnp.float32) \
        * xs * ws[None, :]
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6)
    # int32 accumulation exact up to the fp32 rescale rounding
    out_i = np.asarray(out / (xs * ws[None, :]))
    int_ref = np.asarray(xq.astype(jnp.int32) @ wq.astype(jnp.int32))
    assert np.allclose(out_i, int_ref, rtol=1e-5, atol=0.5)


# ---------------------------------------------------------------------------
# ssd (Mamba-2 chunked scan)
# ---------------------------------------------------------------------------

@sweep(n_cases=6, seed=5)
def test_ssd_pallas_sweep(rng):
    b = int(rng.integers(1, 3))
    s = int(rng.integers(1, 5)) * 32
    h = int(rng.choice([2, 4]))
    p = int(rng.choice([16, 32]))
    g = int(rng.choice([1, 2]))
    n = int(rng.choice([8, 16]))
    chunk = int(rng.choice([16, 32, s]))
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal((h,)) * 0.5, jnp.float32))
    B = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    out = ssd_op(x, dt, A, B, C, chunk=chunk, D_skip=D)
    ref, _ = ssd_recurrent_ref(x, dt, A, B, C, D_skip=D)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_ssd_jnp_chunk_invariance():
    """Chunk size must not change the result (scan-vs-parallel duality)."""
    from repro.layers.mamba2 import ssd_chunked
    key = jax.random.PRNGKey(7)
    b, s, h, p, g, n = 2, 96, 2, 16, 1, 8
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, g, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n))
    y32, st32 = ssd_chunked(x, dt, A, B, C, chunk=32)
    y96, st96 = ssd_chunked(x, dt, A, B, C, chunk=96)
    assert_allclose(np.asarray(y32), np.asarray(y96), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(st32), np.asarray(st96), rtol=1e-4, atol=1e-4)
