"""MoE: dense-path semantics + shard_map path equivalence on fake devices.

The shard_map modes (a2a / repl / tp) must match the dense reference
exactly when nothing overflows capacity (generous capacity factor) —
verified per mode in a subprocess with 8 fake devices.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from repro.layers.moe import MoeConfig, _capacity, init_moe, moe_dense

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_dense_moe_basics():
    cfg = MoeConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                    capacity_factor=2.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = jax.jit(lambda p, x: moe_dense(p, x, cfg))(params, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    assert float(aux) > 0


def test_dense_moe_capacity_drops():
    """With capacity_factor -> 0 every token drops and output is ~zero."""
    cfg = MoeConfig(d_model=16, d_ff=32, n_experts=64, top_k=1,
                    capacity_factor=1e-9)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 2048, 16))
    y, _ = moe_dense(params, x, cfg)
    # capacity floor is 8 slots/expert; most of 2048 tokens must drop
    dropped = float(jnp.mean(jnp.all(y == 0.0, axis=-1)))
    assert dropped > 0.5, dropped


def test_top1_is_plain_ffn():
    """n_experts=1, top_k=1, ample capacity == the expert MLP exactly."""
    cfg = MoeConfig(d_model=16, d_ff=32, n_experts=1, top_k=1,
                    capacity_factor=4.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, _ = moe_dense(params, x, cfg)
    xt = x.reshape(16, 16)
    h = jnp.einsum("td,df->tf", xt, params["w_in"][0])
    g = jnp.einsum("td,df->tf", xt, params["w_gate"][0])
    ref = jnp.einsum("tf,fd->td", jax.nn.silu(g) * h, params["w_out"][0])
    assert_allclose(np.asarray(y.reshape(16, 16)), np.asarray(ref),
                    rtol=2e-4, atol=2e-4)


def _run_mode(mode_body: str) -> dict:
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.distributed.ctx import use_sharding
        from repro.distributed.partition import make_ctx
        from repro.layers.moe import (
            MoeConfig, init_moe, moe_dense, moe_shard_map)
    """) + textwrap.dedent(mode_body)
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_shard_map_a2a_matches_dense():
    """E=8 experts on (2 data x 4 model), S sharded -> a2a mode."""
    r = _run_mode("""
        cfg = MoeConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                        capacity_factor=8.0)
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(mesh)
        ref, aux_ref = moe_dense(params, x, cfg)
        with use_sharding(ctx), mesh:
            y, aux = jax.jit(
                lambda p, x: moe_shard_map(p, x, cfg, ctx))(params, x)
        err = float(jnp.max(jnp.abs(y - ref)))
        print(json.dumps({"err": err, "aux": float(aux),
                          "aux_ref": float(aux_ref)}))
    """)
    assert r["err"] < 2e-4, r
    assert abs(r["aux"] - r["aux_ref"]) < 1e-4


def test_shard_map_repl_matches_dense():
    """S=1 (decode): tokens replicated over model -> repl mode."""
    r = _run_mode("""
        cfg = MoeConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                        capacity_factor=8.0)
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 1, 32))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(mesh)
        ref, _ = moe_dense(params, x, cfg)
        with use_sharding(ctx), mesh:
            y, aux = jax.jit(
                lambda p, x: moe_shard_map(p, x, cfg, ctx))(params, x)
        print(json.dumps({"err": float(jnp.max(jnp.abs(y - ref)))}))
    """)
    assert r["err"] < 2e-4, r


def test_shard_map_tp_matches_dense():
    """E=2 experts on a 4-way model axis -> tp mode (grok-1's regime)."""
    r = _run_mode("""
        cfg = MoeConfig(d_model=32, d_ff=64, n_experts=2, top_k=1,
                        capacity_factor=8.0)
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(mesh)
        ref, _ = moe_dense(params, x, cfg)
        with use_sharding(ctx), mesh:
            y, aux = jax.jit(
                lambda p, x: moe_shard_map(p, x, cfg, ctx))(params, x)
        print(json.dumps({"err": float(jnp.max(jnp.abs(y - ref)))}))
    """)
    assert r["err"] < 2e-4, r


def test_shard_map_grad_flows():
    """The a2a path must be differentiable (training uses it)."""
    r = _run_mode("""
        cfg = MoeConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                        capacity_factor=4.0)
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(mesh)

        def loss(p, x):
            y, aux = moe_shard_map(p, x, cfg, ctx)
            return jnp.sum(y ** 2) + aux

        with use_sharding(ctx), mesh:
            g = jax.jit(jax.grad(loss))(params, x)
        norms = {k: float(jnp.linalg.norm(v)) for k, v in
                 [("w_in", g["w_in"]), ("w_out", g["w_out"])]}
        finite = all(np.isfinite(v) for v in norms.values())
        print(json.dumps({"finite": finite, "w_in": norms["w_in"]}))
    """)
    assert r["finite"] and r["w_in"] > 0
