"""Observability layer (ISSUE 9): tracer spans through the serving
runtime (async host loop + watchdog on a manual clock), ring bounding,
Chrome/Perfetto export round-trip, drift-report math on a scripted
timer, Prometheus text escaping, and the benchmark ledger schema.

The tracer tests run against the fault-tolerance suite's fake-cache
idiom: host-only scripted executors, so hundreds of span assertions
stay fast and deterministic."""
import json
import math
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.common.errors import ExecutorError
from repro.obs import (
    BENCH_SCHEMA, TRACE_SCHEMA, MetricsRegistry, Tracer, bench_result,
    escape_label, load_result, request_chains, validate_chrome_trace,
    validate_result, write_result)
from repro.serving.scheduler import (
    ManualClock, MicroBatchScheduler, Request)
from repro.serving.telemetry import Telemetry


# -- fakes (the test_fault_tolerance idiom) --------------------------------

class FakeExecutor:
    def __init__(self, cache, bucket):
        self.cache, self.bucket = cache, bucket

    def __call__(self, params, x):
        if self.cache.call_faults:
            raise self.cache.call_faults.pop(0)
        return np.full((int(x.shape[0]), 4), float(self.bucket),
                       np.float32)


class FakeCache:
    def __init__(self, *, buckets=(1, 2, 4), call_faults=()):
        self.buckets = tuple(buckets)
        self.precision = "auto"
        self.telemetry = Telemetry()
        self.call_faults = list(call_faults)
        self.degrades = []

    def get(self, batch, resolution):
        return FakeExecutor(self, batch)

    def degrade(self, batch, resolution, *, site=None):
        self.degrades.append((batch, resolution, site))

    def pin_fp(self, batch, resolution):
        pass


def _reqs(n, res=32, **kw):
    return [Request(rid=i, image=np.zeros((res, res, 3), np.float32), **kw)
            for i in range(n)]


# -- tracer core -----------------------------------------------------------

def test_span_nesting_and_manual_clock():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    root = tr.begin("request", rid=7)
    clock.advance(0.010)
    with tr.span("queue", parent=root):
        clock.advance(0.005)
    tr.event(root, "retry", attempt=1)
    clock.advance(0.001)
    tr.end(root, status="completed")
    q, = tr.spans("queue")
    r, = tr.spans("request")
    assert q.parent_id == r.span_id and q.track == r.track
    assert q.start == pytest.approx(0.010)
    assert q.duration == pytest.approx(0.005)
    assert r.duration == pytest.approx(0.016)
    assert r.attrs["rid"] == 7 and r.attrs["status"] == "completed"
    assert r.event_names() == ("retry",)
    # end is idempotent: the ring holds the span exactly once
    tr.end(r)
    assert len(tr.spans("request")) == 1
    # event on a None span is a guarded no-op (optional handles)
    tr.event(None, "ignored")


def test_ring_bounds_finished_spans():
    tr = Tracer(capacity=8)
    for i in range(20):
        tr.end(tr.begin(f"s{i}"))
    assert len(tr) == 8
    assert tr.dropped == 12
    assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(12, 20)]
    # open spans are not subject to the ring
    tr.begin("open")
    assert [s.name for s in tr.open_spans()] == ["open"]


def test_chrome_export_round_trips_through_json(tmp_path):
    clock = ManualClock()
    tr = Tracer(clock=clock)
    root = tr.begin("request", rid=1, resolution=32)
    q = tr.begin("queue", parent=root)
    clock.advance(0.004)
    tr.end(q)
    tr.event(root, "retry", attempt=1)
    tr.end(root, status="completed")
    b = tr.begin("dispatch", rids=[1], bucket=1, resolution=32)
    tr.end(b)
    for name in ("device", "finalize"):
        tr.end(tr.begin(name, rids=[1], bucket=1, resolution=32))
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())          # the Perfetto load path
    assert doc["schema"] == TRACE_SCHEMA
    assert validate_chrome_trace(doc) == 5
    spans = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    assert spans["queue"]["dur"] == pytest.approx(4000.0)  # µs
    assert spans["queue"]["args"]["parent_id"] \
        == spans["request"]["args"]["span_id"]
    chains = request_chains(doc)
    assert set(chains) == {1}
    c = chains[1]
    assert {"queue"} <= c["children"]
    assert {"dispatch", "device", "finalize"} <= c["member_of"]
    assert c["events"] == ("retry",)


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="schema"):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"schema": TRACE_SCHEMA})
    bad = {"schema": TRACE_SCHEMA, "traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "x", "ts": 0.0,
         "dur": -1.0, "args": {"span_id": 1}}]}
    with pytest.raises(ValueError, match="bad dur"):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError, match="unknown ph"):
        validate_chrome_trace({"schema": TRACE_SCHEMA, "traceEvents": [
            {"ph": "B", "pid": 1, "tid": 0, "name": "x"}]})


def test_trace_module_never_imports_jax():
    """The hot-path constraint: obs.trace must stay importable (and
    import-side-effect-free) without jax — span recording on the
    dispatch path may not touch the device stack."""
    code = ("import sys; import repro.obs.trace; "
            "assert 'jax' not in sys.modules, 'obs.trace pulled in jax'; "
            "import repro.obs; "
            "assert 'jax' not in sys.modules, 'repro.obs pulled in jax'")
    subprocess.run([sys.executable, "-c", code], check=True)


# -- tracer x scheduler: the instrumented runtime --------------------------

def test_scheduler_emits_complete_request_chains():
    clock = ManualClock()
    tracer = Tracer(clock=clock)
    cache = FakeCache()
    sched = MicroBatchScheduler(cache, None, clock=clock, tracer=tracer)
    reqs = _reqs(4)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)
    sched.finalize()
    assert all(r.status == "completed" for r in reqs)
    assert not tracer.open_spans()
    chains = request_chains(tracer.to_chrome())
    assert set(chains) == {0, 1, 2, 3}
    for c in chains.values():
        assert {"queue"} <= c["children"]
        assert {"dispatch", "device", "finalize"} <= c["member_of"]


def test_retry_opens_fresh_queue_residency_span():
    clock = ManualClock()
    tracer = Tracer(clock=clock)
    cache = FakeCache(call_faults=[ExecutorError("flaky launch")])
    sched = MicroBatchScheduler(cache, None, clock=clock, tracer=tracer,
                                backoff_ms=10.0)
    reqs = _reqs(2, deadline_ms=5.0)
    for r in reqs:
        sched.submit(r)
    clock.advance(0.01)
    sched.step()                       # dispatch fails -> retry parked
    clock.advance(0.02)
    sched.step()
    sched.finalize()
    assert all(r.status == "completed" and r.retries == 1 for r in reqs)
    # one queue residency per stay: original + post-backoff requeue
    for root in tracer.spans("request"):
        qspans = [s for s in tracer.spans("queue")
                  if s.parent_id == root.span_id]
        assert len(qspans) == 2, [s.attrs for s in qspans]
        assert qspans[1].attrs.get("retry") == 1
        assert "retry" in root.event_names()
        assert root.attrs["status"] == "completed"


def test_watchdog_fires_as_trace_events_on_manual_clock():
    clock = ManualClock()
    tracer = Tracer(clock=clock)
    cache = FakeCache()
    sched = MicroBatchScheduler(cache, None, clock=clock, tracer=tracer,
                                watchdog_ms=50.0, backoff_ms=0.0)
    reqs = _reqs(2)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)             # in flight, NOT finalized
    clock.advance(0.2)                 # blow the 50 ms watchdog bound
    sched.step(drain=True)             # sweep declares the batch hung
    assert cache.telemetry.counters.get("watchdog_fired") == 1
    dev = [s for s in tracer.spans("device")
           if s.attrs.get("error") == "watchdog"]
    assert len(dev) == 1 and dev[0].finished
    sched.finalize()
    while sched.outstanding():
        sched.step(drain=True)
        sched.finalize()
        clock.advance(0.1)
    assert all(r.status == "completed" for r in reqs)
    for root in tracer.spans("request"):
        assert "watchdog_fired" in root.event_names()
        assert root.attrs["status"] == "completed"
    assert not tracer.open_spans()


def test_async_host_loop_traces_without_span_leaks():
    """start()/stop(): spans record correctly from the background
    thread — every request chain completes, nothing stays open."""
    clock = ManualClock()
    tracer = Tracer(clock=clock)
    cache = FakeCache()
    sched = MicroBatchScheduler(cache, None, clock=clock, tracer=tracer,
                                watchdog_ms=500.0)
    sched.start(poll_s=0.001)
    try:
        reqs = _reqs(8, deadline_ms=5.0)
        for r in reqs:
            sched.submit(r)
        clock.advance(0.05)            # make stragglers due for the loop
        deadline = time.monotonic() + 10.0
        while any(r.status == "pending" for r in reqs):
            assert time.monotonic() < deadline, \
                [(r.rid, r.status) for r in reqs]
            time.sleep(0.002)
    finally:
        sched.stop()
    assert all(r.status == "completed" for r in reqs)
    assert not tracer.open_spans(), \
        [s.name for s in tracer.open_spans()]
    chains = request_chains(tracer.to_chrome())
    assert len(chains) == 8
    for c in chains.values():
        assert {"queue"} <= c["children"]
        assert {"dispatch", "device", "finalize"} <= c["member_of"]


# -- drift report math on a scripted timer ---------------------------------

def test_drift_report_math_scripted_timer():
    jax = pytest.importorskip("jax")
    from repro.core.efficientvit import B1_SMOKE
    from repro.core.program import lower
    from repro.obs.profile import SiteProfiler, drift_report

    program = lower(B1_SMOKE, batch=1, image_size=32)
    ticks = iter(x * 1e-3 for x in range(10_000))
    prof = SiteProfiler(clock=lambda: next(ticks), sync=lambda out: out)
    for _ in range(2):                     # two scripted repeats
        for site in program.sites:
            prof.begin(site)
            prof.end(site, out=None)
    assert prof.repeats == 2
    # each begin->end spans exactly one 1 ms tick
    rep = drift_report(program, prof, plan=None, precision="fp")
    assert rep.precision == "fp" and rep.repeats == 2
    assert len(rep.rows) == len(program.sites)
    assert rep.finite()
    for r in rep.rows:
        assert r["measured_ms"] == pytest.approx(1.0)
        assert r["predicted_cycles"] > 0
        assert r["drift"] == pytest.approx(
            r["measured_ms"] / r["predicted_ms"])
    # the zero-MAC gap site is charged its memory-bound boundary floor
    gap = rep.row("head.gap")
    assert gap["predicted_ms"] > 0
    assert rep.drift == pytest.approx(
        rep.measured_ms / rep.predicted_ms)
    doc = rep.to_dict()
    json.dumps(doc)                        # ledger-ready
    assert doc["rows"][0]["site"] == program.sites[0].name
    # partial profiles refuse to reconcile
    with pytest.raises(KeyError):
        drift_report(program, SiteProfiler(), plan=None)


# -- metrics registry ------------------------------------------------------

def test_prometheus_escaping_and_text_format():
    assert escape_label('a\\b"c\nd') == 'a\\\\b\\"c\\nd'
    reg = MetricsRegistry(namespace="repro")
    reg.counter("req", "requests").inc(3, route='vis"ion\n', mesh="a\\b")
    text = reg.prometheus_text()
    assert '# TYPE repro_req counter' in text
    assert 'route="vis\\"ion\\n"' in text
    assert 'mesh="a\\\\b"' in text
    assert text.endswith("\n")


def test_histogram_cumulative_buckets_text():
    reg = MetricsRegistry()
    h = reg.histogram("build_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.prometheus_text()
    assert 'repro_build_s_bucket{le="0.1"} 1' in text
    assert 'repro_build_s_bucket{le="1"} 2' in text
    assert 'repro_build_s_bucket{le="+Inf"} 3' in text
    assert 'repro_build_s_sum 5.55' in text
    assert 'repro_build_s_count 3' in text


def test_registry_renders_telemetry_with_p99():
    tel = Telemetry()
    tel.record_dispatch((4, 32, "auto"), 3, 4, queue_depth=2,
                        wait_ms=[1.0, 2.0, 3.0])
    tel.record_latency((4, 32, "auto"), [10.0, 20.0])
    tel.count("completed", 3)
    reg = MetricsRegistry(telemetry=tel)
    text = reg.prometheus_text()
    assert "repro_completed_total 3" in text
    assert ('repro_bucket_samples_total{bucket="4",precision="auto",'
            'resolution="32"} 3') in text
    assert 'quantile="0.99"' in text
    doc = reg.to_json()
    json.dumps(doc)
    names = {f["name"] for f in doc["families"]}
    assert {"repro_bucket_occupancy", "repro_bucket_wait_ms",
            "repro_bucket_latency_ms"} <= names


def test_telemetry_table_renders_dash_for_empty_series():
    tel = Telemetry()
    tel.record_dispatch((4, 32, "auto"), 4, 4)   # no waits, no latencies
    table = tel.table()
    assert "p50/p95/p99" in table
    row = next(line for line in table.splitlines() if "4x32xauto" in line)
    assert "-/-/-" in row
    assert "nan" not in table.lower()


# -- benchmark ledger ------------------------------------------------------

def test_ledger_round_trip(tmp_path):
    doc = bench_result(
        "kernel_bench",
        config={"backend": "cpu"},
        metrics={"max_err": np.float32(1e-3), "shape": (2, 3),
                 "bad": float("nan")},
        gates={"err": True})
    assert doc["schema"] == BENCH_SCHEMA
    assert doc["metrics"]["max_err"] == pytest.approx(1e-3)
    assert doc["metrics"]["shape"] == [2, 3]       # tuples -> lists
    assert doc["metrics"]["bad"] is None           # NaN -> null
    path = tmp_path / "BENCH_X.json"
    write_result(str(path), doc)
    assert load_result(str(path)) == doc
    assert json.loads(path.read_text())["name"] == "kernel_bench"


def test_ledger_rejects_malformed():
    with pytest.raises(ValueError, match="unknown benchmark"):
        bench_result("nonsense_bench")
    good = bench_result("e2e_latency")
    bad = dict(good, schema=99)
    with pytest.raises(ValueError, match="schema"):
        validate_result(bad)
    bad = dict(good, gates={"g": "yes"})
    with pytest.raises(ValueError, match="not a bool"):
        validate_result(bad)
    bad = dict(good)
    del bad["metrics"]
    with pytest.raises(ValueError, match="metrics"):
        validate_result(bad)


def test_ledger_fixture_is_valid():
    """The committed serving_bench smoke fixture stays loadable and
    self-judging (every gate green)."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "ledger", "BENCH_SMOKE.json")
    doc = load_result(path)
    assert doc["name"] == "serving_bench"
    assert doc["gates"] and all(doc["gates"].values()), doc["gates"]
    assert doc["metrics"]["trace"]["fp"]["chains"] \
        == doc["config"]["n_requests"]
