"""Pipeline parallelism: exactness of the GPipe schedule (fwd + bwd)
against a sequential reference, on 8 fake devices in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline import pipelined_apply, split_stages
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pipeline_fwd_bwd_exact():
    r = _run("""
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        L, D, M, mb, S = 4, 16, 4, 2, 8
        Ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, D))

        def stage_fn(params, h):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, h, params)[0]

        def seq(Ws, xi):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, xi, Ws)[0]

        stages = split_stages(Ws, 2)
        sp = P(None, None, None, None)
        out = pipelined_apply(stage_fn, stages, x, mesh=mesh, extra_specs=sp)
        ref = jax.vmap(lambda xi: seq(Ws, xi))(x)
        fwd_err = float(jnp.max(jnp.abs(out - ref)))

        g_pp = jax.grad(lambda st, x: jnp.sum(pipelined_apply(
            stage_fn, st, x, mesh=mesh, extra_specs=sp) ** 2))(stages, x)
        g_seq = jax.grad(lambda W, x: jnp.sum(
            jax.vmap(lambda xi: seq(W, xi))(x) ** 2))(Ws, x)
        bwd_err = float(jnp.max(jnp.abs(g_pp.reshape(L, D, D) - g_seq)))
        print(json.dumps({"fwd": fwd_err, "bwd": bwd_err}))
    """)
    assert r["fwd"] < 2e-5, r
    assert r["bwd"] < 2e-4, r


def test_pipeline_dp_inside_stage():
    """Batch sharded over data inside the fully-manual pipeline: grads
    must psum across data replicas (shard_map AD)."""
    r = _run("""
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        L, D, M, mb, S = 2, 8, 2, 8, 4
        Ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, S, D))

        def stage_fn(params, h):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, h, params)[0]

        def seq(Ws, xi):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, xi, Ws)[0]

        stages = split_stages(Ws, 2)
        sp = P(None, "data", None, None)
        g_pp = jax.grad(lambda st, x: jnp.sum(pipelined_apply(
            stage_fn, st, x, mesh=mesh, extra_specs=sp) ** 2))(stages, x)
        g_seq = jax.grad(lambda W, x: jnp.sum(
            jax.vmap(lambda xi: seq(W, xi))(x) ** 2))(Ws, x)
        err = float(jnp.max(jnp.abs(g_pp.reshape(L, D, D) - g_seq)))
        print(json.dumps({"err": err}))
    """)
    assert r["err"] < 2e-4, r
