"""Program IR + kernel registry: one lowering, consumed everywhere.

The contract under test: ``core.program.lower`` is the single source of
truth — the fusion plan's site set, the layer manifest's MACs, and the
analytic HBM accounting all derive from the same ``Site`` sequence and
therefore cannot drift from what ``execute`` actually runs.  Plus the
launch-count drift gate: EfficientViT-B1 @224 fuses to exactly
``core.fusion.EXPECTED_B1_FUSED_LAUNCHES`` launches until someone
updates that expectation explicitly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.efficientvit import (
    B1, B1_SMOKE, efficientvit, init_efficientvit, layer_manifest,
    total_macs)
from repro.core.fusion import (
    EXPECTED_B1_FUSED_LAUNCHES, EXPECTED_B1_FUSED_LAUNCHES_INT8,
    EXPECTED_B1_SUPERSITE_LAUNCHES, EXPECTED_B1_SUPERSITE_LAUNCHES_INT8,
    build_plan, launch_counts, plan_program, plan_report, site_traffic)
from repro.core.program import FUSIBLE_KINDS, execute, lower, manifest, params_at
from repro.core.quantization import quantize_efficientvit
from repro.kernels import registry

# Legacy hand-written layer_manifest totals (pre-IR walk), pinned: the
# IR-derived manifest must reproduce them exactly.
LEGACY_TOTAL_MACS = {"B1": 518_963_712, "B1_SMOKE": 2_038_080}
LEGACY_N_RECORDS = {"B1": 90, "B1_SMOKE": 36}


# ---------------------------------------------------------------------------
# IR consistency: one lowering feeds plan, manifest, accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [B1, B1_SMOKE], ids=["b1", "smoke"])
def test_program_sites_match_plan_keys(cfg, tmp_autotune_cache):
    """Fusible site set == build_plan decision keys (both precisions)."""
    program = lower(cfg, batch=1)
    params = init_efficientvit(jax.random.PRNGKey(0), cfg)
    ir_names = [s.name for s in program.fusible()]
    assert len(set(ir_names)) == len(ir_names)      # unique
    for tree in (params, quantize_efficientvit(params)):
        plan = build_plan(tree, cfg, batch=1, autotune=False)
        assert list(plan.decisions) == ir_names
        for s in program.fusible():
            d = plan.get(s.name)
            assert d.kind == s.kind and s.kind in FUSIBLE_KINDS


@pytest.mark.parametrize("name,cfg", [("B1", B1), ("B1_SMOKE", B1_SMOKE)])
def test_ir_manifest_matches_legacy(name, cfg):
    """total_macs / record count from the IR == the legacy manifest."""
    assert total_macs(cfg) == LEGACY_TOTAL_MACS[name]
    records = layer_manifest(cfg)
    assert len(records) == LEGACY_N_RECORDS[name]
    assert records == manifest(lower(cfg))          # shim is the IR


def test_param_paths_resolve():
    """Every site's param_path indexes a real subtree of the init tree."""
    params = init_efficientvit(jax.random.PRNGKey(1), B1_SMOKE)
    for site in lower(B1_SMOKE).sites:
        if site.param_path:
            assert params_at(params, site.param_path) is not None


@pytest.mark.parametrize("precision", ["fp", "int8"])
def test_plan_report_matches_site_traffic(precision, tmp_autotune_cache):
    """plan_report HBM totals == registry-side accounting from the IR.

    The decisions' frozen shape tuples and the Program's site geometry
    are two derivations of the same numbers; they must agree per site
    for both precisions."""
    program = lower(B1_SMOKE, batch=1)
    params = init_efficientvit(jax.random.PRNGKey(2), B1_SMOKE)
    if precision == "int8":
        params = quantize_efficientvit(params)
    plan = plan_program(program, params, autotune=False)
    rows = {r["site"]: r for r in plan_report(plan)}
    # the delivered column reads epilogues off the ANNOTATED program
    # (the executor-cache view) — plus the producer's epilogue for q_in
    annotated = program.with_epilogues(plan)
    prev = {cur.name: prv for prv, cur in
            zip(annotated.sites, annotated.sites[1:])}
    for site in annotated.fusible():
        d = plan.get(site.name)
        q_in = prev[site.name].epilogue.emits_q
        want = site_traffic(site, precision=d.precision, q_in=q_in)
        got = rows[site.name]
        for k in ("hbm_unfused", "hbm_w", "launches_ref"):
            assert got[k] == want[k], (site.name, k)
        if got["fused"]:
            assert got["hbm_fused"] == want["hbm_fused"]
            assert got["hbm_delivered"] == want["hbm_delivered"]
            assert got["launches_fused"] == want["launches_fused"]


def test_execute_is_the_forward(tmp_autotune_cache):
    """The efficientvit() shim and raw execute() are the same function,
    and the plan-routed program agrees with the reference program."""
    key = jax.random.PRNGKey(3)
    params = init_efficientvit(key, B1_SMOKE)
    x = jax.random.normal(key, (2, 64, 64, 3))
    program = lower(B1_SMOKE, batch=2, image_size=64)
    ref = execute(program, params, x)
    shim = efficientvit(params, x, B1_SMOKE)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(shim))
    plan = plan_program(program, params, autotune=False)
    fus = execute(program, params, x, plan=plan)
    assert_allclose(np.asarray(fus), np.asarray(ref), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# drift gate (CI): B1 @224 launch counts are pinned
# ---------------------------------------------------------------------------

def test_b1_fused_launch_drift_gate(tmp_autotune_cache):
    """19 fused launches at B1/224 fp and 26 at int8: super-site
    grouping collapses the S1 pair (-1) and the S2 triple (-2) into one
    launch each at both precisions, down from the per-site 22/29 (the
    grouped aggregation kernel adds one launch per scale per fused MSA
    module).  If a lowering or planner change moves any of these,
    update EXPECTED_B1_SUPERSITE_LAUNCHES / _INT8 (or, with
    supersites=False, EXPECTED_B1_FUSED_LAUNCHES / _INT8) and the
    EXPERIMENTS.md narrative explicitly — this test failing is the
    drift alarm, not an inconvenience to silence."""
    program = lower(B1, batch=1)
    assert len(program.fusible()) == EXPECTED_B1_FUSED_LAUNCHES
    params = init_efficientvit(jax.random.PRNGKey(4), B1)
    expected = {"fp": EXPECTED_B1_SUPERSITE_LAUNCHES,
                "int8": EXPECTED_B1_SUPERSITE_LAUNCHES_INT8}
    persite = {"fp": EXPECTED_B1_FUSED_LAUNCHES,
               "int8": EXPECTED_B1_FUSED_LAUNCHES_INT8}
    for prec, tree in (("fp", params),
                       ("int8", quantize_efficientvit(params))):
        plan = plan_program(program, tree, autotune=False)
        lc = launch_counts(plan)
        assert lc["fused"] == expected[prec], (prec, lc)
        assert lc["reference"] > lc["fused"]
        assert {g.name: list(g.members) for g in plan.groups.values()} \
            == {"S1.ss0": ["S1.mb0", "S1.mb1"],
                "S2.ss0": ["S2.mb0", "S2.mb1", "S2.mb2"]}
        # the per-site expectation is still what the planner produces
        # with the grouping pass disabled
        flat = plan_program(program, tree, autotune=False,
                            supersites=False)
        assert launch_counts(flat)["fused"] == persite[prec], prec
        assert not flat.groups


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------

def test_registry_builtin_registrations():
    have = registry.available()
    for kind in ("dsconv", "mbconv", "msa"):
        for prec in ("fp", "int8"):
            assert (kind, prec) in have
            impl = registry.get_kernel(kind, prec)
            assert impl.kind == kind and impl.precision == prec
    # the grouped MSA aggregation kernel ships int8-only (the ROADMAP
    # worked example, landed); the probe resolves it without an fp twin
    assert ("group_agg", "int8") in have
    assert registry.get_probe("group_agg").precision == "int8"
    with pytest.raises(KeyError, match="no kernel registered"):
        registry.get_kernel("group_agg", "fp")
    # int8-dataflow capability flags on the FIX8 impls
    for kind in ("dsconv", "mbconv", "msa"):
        impl = registry.get_kernel(kind, "int8")
        assert impl.takes_q and impl.emits_q
        assert not registry.get_kernel(kind, "fp").emits_q


def test_registry_new_kernel_slots_in():
    """The worked example from the registry docstring: a new (kind,
    precision) registers and resolves without touching the planner."""
    @registry.register
    class DummyKernel(registry.KernelBase):
        kind, precision, dtype = "unit_dummy", "int8", "i8"

        def apply(self, params, x, site, decision=None, *, interpret=None):
            return x

    try:
        impl = registry.get_kernel("unit_dummy", "int8")
        assert impl.apply(None, 7, None) == 7
        assert ("unit_dummy", "int8") in registry.available()
    finally:
        registry.unregister("unit_dummy", "int8")
    assert ("unit_dummy", "int8") not in registry.available()


def test_registry_new_kernel_plans_and_executes(tmp_autotune_cache):
    """The additive flow end-to-end: a kind unknown to core.fusion /
    core.program still plans (enabled by default, probe resolution,
    decision shape, report) and executes (apply when fused, ref when
    not) purely via its registration."""
    from repro.core.program import Program, Site

    @registry.register
    class DoubleKernel(registry.KernelBase):
        kind, precision, dtype = "unit_double", "fp", "f32"

        def site_precision(self, params):
            return "fp"

        def tune(self, site, *, autotune=True, interpret=None):
            return {"block": 1}

        def apply(self, params, x, site, decision=None, *, interpret=None):
            return x * 2.0

        def ref(self, params, x, site, **kw):
            return x * 2.0

    try:
        site = Site("X.unit0", "unit_double", "X", (), (1, 4, 4, 8),
                    (1, 4, 4, 8))
        program = Program(B1_SMOKE, 1, 4, (site,))
        assert program.fusible() == (site,)
        plan = plan_program(program, {}, autotune=False)
        d = plan.get("X.unit0")
        assert d.fused and d.reason == "ok" and d.blocks == {"block": 1}
        x = jnp.ones((1, 4, 4, 8))
        want = np.full((1, 4, 4, 8), 2.0, np.float32)
        np.testing.assert_array_equal(
            np.asarray(execute(program, {}, x, plan=plan)), want)
        np.testing.assert_array_equal(          # plan=None -> impl.ref
            np.asarray(execute(program, {}, x)), want)
        row = plan_report(plan)[0]              # no analytic model: zeros
        assert row["launches_fused"] == 1 and row["hbm_w"] == 0
    finally:
        registry.unregister("unit_double", "fp")


def test_registry_precision_policies():
    """Conv kinds demote on mismatch; MSA never falls back (its core is
    precision-agnostic, only the projections switch)."""
    conv = registry.get_kernel("mbconv", "fp")
    assert conv.resolve_precision("int8", "auto") == ("int8", None)
    assert conv.resolve_precision("int8", "fp") == ("fp", "quantized")
    assert conv.resolve_precision("fp", "int8") == ("fp", "not-quantized")
    assert conv.resolve_precision("mixed", "auto") == ("fp", "mixed")
    msa = registry.get_kernel("msa", "fp")
    assert msa.resolve_precision("int8", "fp") == ("fp", None)
    assert msa.resolve_precision("fp", "int8") == ("fp", None)
    assert msa.resolve_precision("int8", "auto") == ("int8", None)
