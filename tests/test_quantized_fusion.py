"""FIX8 fused path: int8 megakernels with in-kernel requantization.

The contract under test: a ``quantize_efficientvit`` tree routed through
a ``build_plan(..., precision="auto"|"int8")`` plan must fuse every site
the fp plan fuses (zero ``"quantized"`` fallbacks) and agree with the
int8 *reference* path — bit-exactly at batch 1, where the in-kernel
per-batch-element requantization scales coincide with the reference
whole-tensor ones, and within quantization noise otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from proptest import sweep

from repro.core.efficientvit import (
    B1_SMOKE, dsconv, efficientvit, init_dsconv, init_efficientvit,
    init_mbconv, mbconv)
from repro.core.quantization import (
    calibrate_act_scale, quantize_efficientvit, quantize_tensor)
from repro.kernels.dsconv.kernel import dsconv_fused_int8
from repro.kernels.dsconv.ops import dsconv_apply_int8
from repro.kernels.dsconv.ref import dsconv_int8_ref
from repro.kernels.mbconv.kernel import mbconv_fused_int8
from repro.kernels.mbconv.ops import mbconv_apply_int8
from repro.kernels.mbconv.ref import mbconv_int8_ref


def _rand_q(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)


def _rand_s(rng, n):
    return jnp.asarray(rng.uniform(0.005, 0.05, (n,)), jnp.float32)


# ---------------------------------------------------------------------------
# int8 megakernels vs jnp oracles (stride 1/2, per-channel scales,
# ragged c_out tiles)
# ---------------------------------------------------------------------------

@sweep(n_cases=8, seed=21)
def test_mbconv_int8_fused_sweep(rng):
    b = int(rng.integers(1, 3))
    hw = int(rng.choice([8, 12, 16]))
    c = int(rng.choice([4, 8, 16]))
    m = c * int(rng.choice([2, 4]))
    f = int(rng.choice([8, 16, 24]))
    stride = int(rng.choice([1, 2]))
    bf = int(rng.choice([8, 64, f]))  # exercises ragged c_out tiles
    args = (_rand_q(rng, (b, hw, hw, c)), jnp.float32(rng.uniform(0.01, 0.1)),
            _rand_q(rng, (c, m)), _rand_s(rng, m),
            jnp.asarray(rng.standard_normal((m,)), jnp.float32),
            _rand_q(rng, (3, 3, m)), _rand_s(rng, m),
            jnp.asarray(rng.standard_normal((m,)), jnp.float32),
            _rand_q(rng, (m, f)), _rand_s(rng, f),
            jnp.asarray(rng.standard_normal((f,)), jnp.float32))
    out = mbconv_fused_int8(*args, stride=stride, block_f=bf)
    ref = mbconv_int8_ref(*args, stride=stride)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@sweep(n_cases=6, seed=22)
def test_dsconv_int8_fused_sweep(rng):
    b = int(rng.integers(1, 3))
    hw = int(rng.choice([8, 12]))
    c = int(rng.choice([4, 8]))
    f = int(rng.choice([8, 12]))
    stride = int(rng.choice([1, 2]))
    bf = int(rng.choice([4, 128]))
    args = (_rand_q(rng, (b, hw, hw, c)), jnp.float32(rng.uniform(0.01, 0.1)),
            _rand_q(rng, (3, 3, c)), _rand_s(rng, c),
            jnp.asarray(rng.standard_normal((c,)), jnp.float32),
            _rand_q(rng, (c, f)), _rand_s(rng, f),
            jnp.asarray(rng.standard_normal((f,)), jnp.float32))
    out = dsconv_fused_int8(*args, stride=stride, block_f=bf)
    ref = dsconv_int8_ref(*args, stride=stride)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# apply wrappers vs the reference quantized model blocks (conv2d_int8
# chain).  Batch 1: in-kernel requant scales == reference scales.
# ---------------------------------------------------------------------------

def test_mbconv_apply_int8_matches_quantized_block():
    key = jax.random.PRNGKey(0)
    for stride in (1, 2):
        qp = quantize_efficientvit(init_mbconv(key, 8, 16, 4, jnp.float32))
        x = jax.random.normal(jax.random.fold_in(key, stride), (1, 16, 16, 8))
        ref = mbconv(qp, x, stride=stride)
        out = mbconv_apply_int8(qp, x, stride=stride, block_f=128)
        assert_allclose(np.asarray(out), np.asarray(ref),
                        rtol=1e-5, atol=1e-5)


def test_dsconv_apply_int8_matches_quantized_block():
    from repro.core.quantization import conv2d_int8
    key = jax.random.PRNGKey(1)
    qp = quantize_efficientvit(init_dsconv(key, 8, 8, jnp.float32))
    x = jax.random.normal(key, (1, 12, 12, 8))
    ref = dsconv(qp, x)
    out = dsconv_apply_int8(qp, x)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
    # stride 2: SAME anchoring must match the conv2d_int8 chain exactly
    y = jax.nn.hard_swish(conv2d_int8(qp["dw"]["qconv"], x, stride=2,
                                      groups=8))
    ref2 = conv2d_int8(qp["pw"]["qconv"], y)
    out2 = dsconv_apply_int8(qp, x, stride=2)
    assert_allclose(np.asarray(out2), np.asarray(ref2),
                    rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MSA projections through the W8A8 Pallas GEMM
# ---------------------------------------------------------------------------

def test_conv1x1_w8a8_matches_conv2d_int8():
    from repro.core.quantization import conv2d_int8
    from repro.kernels.int8_matmul.ops import conv1x1_w8a8
    rng = np.random.default_rng(3)
    B, H, W, C, F = 2, 7, 7, 16, 48
    x = jnp.asarray(rng.standard_normal((B, H, W, C)), jnp.float32)
    qp = {"q": _rand_q(rng, (1, 1, C, F)), "scale": _rand_s(rng, F),
          "bias": jnp.asarray(rng.standard_normal((F,)), jnp.float32)}
    ref = conv2d_int8(qp, x)
    out = conv1x1_w8a8(qp, x)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_quantized_msa_fused_matches_reference(tmp_autotune_cache):
    from repro.core.fusion import build_plan
    from repro.core.relu_attention import MSAConfig, msa
    key = jax.random.PRNGKey(4)
    params = init_efficientvit(key, B1_SMOKE)
    qparams = quantize_efficientvit(params)
    plan = build_plan(qparams, B1_SMOKE, batch=1, autotune=False)
    site = "S3.evit0.msa"
    assert plan.get(site).precision == "int8"
    c = B1_SMOKE.widths[3]
    mcfg = MSAConfig(c, B1_SMOKE.head_dim, tuple(B1_SMOKE.msa_scales))
    p = qparams["stage3"]["blocks"][0]["msa"]
    x = jax.random.normal(key, (1, 8, 8, c))
    ref = msa(p, x, mcfg)                       # reference quantized path
    out = msa(p, x, mcfg, plan=plan, site=site)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# plan: precision dispatch, no "quantized" bail-outs
# ---------------------------------------------------------------------------

def test_plan_fuses_every_quantized_site(tmp_autotune_cache):
    from repro.core.fusion import build_plan
    key = jax.random.PRNGKey(5)
    params = init_efficientvit(key, B1_SMOKE)
    qparams = quantize_efficientvit(params)
    fp_plan = build_plan(params, B1_SMOKE, batch=1, autotune=False)
    q_plan = build_plan(qparams, B1_SMOKE, batch=1, autotune=False)
    assert not any(d.reason == "quantized"
                   for d in q_plan.decisions.values())
    assert q_plan.n_fused() >= fp_plan.n_fused()
    assert all(d.precision == "int8"
               for d in q_plan.decisions.values() if d.fused)
    # explicit int8 request on the quantized tree: identical routing
    q_plan2 = build_plan(qparams, B1_SMOKE, batch=1, autotune=False,
                         precision="int8")
    assert {d.name: d.precision for d in q_plan2.decisions.values()} == \
        {d.name: d.precision for d in q_plan.decisions.values()}
    # int8 requested on an fp tree -> conv sites demote to reference
    fp_forced = build_plan(params, B1_SMOKE, batch=1, autotune=False,
                           precision="int8")
    conv = [d for d in fp_forced.decisions.values()
            if d.kind in ("dsconv", "mbconv")]
    assert conv and all(not d.fused and d.reason == "not-quantized"
                        for d in conv)


def test_mixed_tree_demotes_gracefully(tmp_autotune_cache):
    """Hand-edited trees (site part-quantized) must fall back, not crash:
    conv sites demote with reason="mixed", an MSA with an fp proj keeps
    its projections on the reference path (precision "fp")."""
    from repro.core.fusion import build_plan
    key = jax.random.PRNGKey(11)
    params = init_efficientvit(key, B1_SMOKE)
    qparams = quantize_efficientvit(params)
    mixed = dict(qparams)
    # un-quantize one mbconv subblock and one msa proj
    mixed["stage1"] = [dict(qparams["stage1"][0],
                            pw1=params["stage1"][0]["pw1"])]
    s3 = {"down": qparams["stage3"]["down"],
          "blocks": [{"msa": dict(qparams["stage3"]["blocks"][0]["msa"],
                                  proj=params["stage3"]["blocks"][0]
                                  ["msa"]["proj"],
                                  proj_bn=params["stage3"]["blocks"][0]
                                  ["msa"]["proj_bn"]),
                      "mbconv": qparams["stage3"]["blocks"][0]["mbconv"]}]}
    mixed["stage3"] = s3
    plan = build_plan(mixed, B1_SMOKE, batch=1, autotune=False)
    d_mb = plan.get("S1.mb0")
    assert not d_mb.fused and d_mb.reason == "mixed"
    d_msa = plan.get("S3.evit0.msa")
    assert d_msa.fused and d_msa.precision == "fp"
    x = jax.random.normal(key, (1, 64, 64, 3))
    out = efficientvit(mixed, x, B1_SMOKE, plan=plan)   # must not crash
    ref = efficientvit(mixed, x, B1_SMOKE)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)


def test_quantized_full_forward_bit_exact_batch1(tmp_autotune_cache):
    from repro.core.fusion import build_plan
    key = jax.random.PRNGKey(6)
    qparams = quantize_efficientvit(init_efficientvit(key, B1_SMOKE))
    plan = build_plan(qparams, B1_SMOKE, batch=1, autotune=False)
    x = jax.random.normal(key, (1, 64, 64, 3))
    ref = jax.jit(lambda p, x: efficientvit(p, x, B1_SMOKE))(qparams, x)
    fus = jax.jit(
        lambda p, x: efficientvit(p, x, B1_SMOKE, plan=plan))(qparams, x)
    assert bool((jnp.argmax(ref, -1) == jnp.argmax(fus, -1)).all())
    assert float(jnp.max(jnp.abs(ref - fus))) < 1e-2
    # conv megakernel sites are bit-identical at batch 1; the msa qkv/proj
    # epilogue may differ by float-mult associativity ulps only
    assert_allclose(np.asarray(fus), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_quantized_full_forward_batch2_within_noise(tmp_autotune_cache):
    from repro.core.fusion import build_plan
    key = jax.random.PRNGKey(7)
    qparams = quantize_efficientvit(init_efficientvit(key, B1_SMOKE))
    plan = build_plan(qparams, B1_SMOKE, batch=2, autotune=False)
    x = jax.random.normal(key, (2, 64, 64, 3))
    ref = efficientvit(qparams, x, B1_SMOKE)
    fus = efficientvit(qparams, x, B1_SMOKE, plan=plan)
    assert bool((jnp.argmax(ref, -1) == jnp.argmax(fus, -1)).all())
    assert float(jnp.max(jnp.abs(ref - fus))) < 1e-2


def test_vision_engine_quantized_mode(tmp_autotune_cache):
    from repro.serving.vision import VisionEngine, VisionServeConfig
    key = jax.random.PRNGKey(8)
    params = init_efficientvit(key, B1_SMOKE)
    eng = VisionEngine.quantized(
        params, B1_SMOKE, VisionServeConfig(microbatch=1, autotune=False))
    assert all(d.precision == "int8"
               for d in eng.plan.decisions.values() if d.fused)
    imgs = jax.random.normal(key, (2, 64, 64, 3))
    logits = eng.logits(imgs)
    assert logits.shape == (2, B1_SMOKE.num_classes)
    # per-sample reference: dynamic act scales are per-microbatch (=1)
    ref = jnp.concatenate([efficientvit(eng.params, imgs[i:i + 1], B1_SMOKE)
                           for i in range(2)])
    assert_allclose(np.asarray(logits), np.asarray(ref),
                    rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# linear_w8a8: calibrated static activation scale
# ---------------------------------------------------------------------------

def test_linear_w8a8_static_scale_matches_dynamic():
    from repro.kernels.int8_matmul.ops import linear_w8a8
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((5, 32)), jnp.float32)
    w_q = _rand_q(rng, (32, 16))
    w_s = _rand_s(rng, 16)
    dyn = linear_w8a8(x, w_q, w_s)
    # the dynamic path is per-batch-element absmax (quantize_act's
    # scheme): each row is quantized with its own scale, so one row's
    # numerics never depend on its batch-mates
    from repro.core.quantization import quantize_act
    qt = quantize_act(x)
    want = (np.asarray(qt.q, np.int32) @ np.asarray(w_q, np.int32)
            ).astype(np.float32) * np.asarray(qt.scale)[:, None] \
        * np.asarray(w_s)[None, :]
    assert_allclose(np.asarray(dyn), want, rtol=1e-5, atol=1e-5)
    # a static scale calibrated on the same tensor agrees to within the
    # coarser per-tensor int8 quantization error
    static = linear_w8a8(x, w_q, w_s, x_scale=calibrate_act_scale(x))
    assert_allclose(np.asarray(static), np.asarray(dyn), rtol=0, atol=0.3)
    # scale calibrated over several batches covers each of them
    xs = [jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
          for _ in range(3)]
    s = calibrate_act_scale(xs)
    for xi in xs:
        got = linear_w8a8(xi, w_q, w_s, x_scale=s)
        xq = jnp.clip(jnp.round(xi / s), -128, 127).astype(jnp.int8)
        want = (xq.astype(jnp.int32) @ w_q.astype(jnp.int32)
                ).astype(jnp.float32) * s * w_s[None, :]
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=1e-5, atol=1e-5)


def test_quantize_with_scale_matches_quantize_tensor():
    from repro.core.quantization import quantize_with_scale
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((6, 6)), jnp.float32)
    q_ref, s = quantize_tensor(x)
    q = quantize_with_scale(x, s)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
