"""Runtime behaviour: checkpoint atomicity/resume, fault-tolerant trainer,
straggler detection, data-pipeline determinism + elastic resharding,
serving engine, schedules, gradient compression.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.checkpoint.checkpoint import (
    CheckpointManager, latest_step, restore, save)
from repro.configs import get_arch, smoke_variant
from repro.data.pipeline import DataConfig, SyntheticLMDataset, host_shard
from repro.models.registry import build_model
from repro.optim.compression import (
    compress_grads_with_feedback, decompress_grads, init_error_feedback)
from repro.optim.schedule import ScheduleConfig, lr_scale
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.trainer import Trainer, TrainerConfig, make_failure_hook
from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.serving.sampler import SamplerConfig


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 7, t, extra={"loss": 1.5})
    out, step, extra = restore(str(tmp_path), t)
    assert step == 7 and extra["loss"] == 1.5
    assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]))
    assert_allclose(np.asarray(out["nested"]["b"]),
                    np.asarray(t["nested"]["b"]))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir (simulated crash mid-write) must be invisible."""
    t = _tree(jax.random.PRNGKey(1))
    save(str(tmp_path), 5, t)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(2))
    for s in (10, 20, 30):
        mgr.save_async(s, t)
    mgr.close()
    kept = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                  if n.startswith("step_"))
    assert kept == [20, 30]


def test_restore_shape_mismatch_raises(tmp_path):
    t = _tree(jax.random.PRNGKey(3))
    save(str(tmp_path), 1, t)
    bad = {"a": jnp.zeros((5, 8)), "nested": {"b": t["nested"]["b"]}}
    with pytest.raises(ValueError):
        restore(str(tmp_path), bad)


# ---------------------------------------------------------------------------
# trainer: loss goes down; failure -> auto-resume continues
# ---------------------------------------------------------------------------

def _trainer(tmp_path, *, steps=30, hook=None, arch="granite-3-2b"):
    cfg = smoke_variant(get_arch(arch))
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8,
                      sharpness=4.0)
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=10,
                         ckpt_dir=str(tmp_path / "ckpt"), log_every=100)
    return Trainer(cfg, data, tcfg, failure_hook=hook)


def test_train_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=40)
    out = tr.run()
    first5 = np.mean(out["losses"][:5])
    last5 = np.mean(out["losses"][-5:])
    assert last5 < first5 - 0.1, (first5, last5)


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    hook = make_failure_hook([25])       # die once at step 25
    tr = _trainer(tmp_path, steps=30, hook=hook)
    out = tr.run()
    # completed despite the failure; ran 30 + (30-20) steps of losses
    assert len(out["losses"]) >= 30
    assert latest_step(str(tmp_path / "ckpt")) == 30


def test_restart_budget_exhausted(tmp_path):
    hook = make_failure_hook([0, 1, 2, 3, 4, 5, 6, 7])
    tr = _trainer(tmp_path, steps=10, hook=hook)
    tr.cfg.max_restarts = 2
    with pytest.raises(RuntimeError, match="restart budget"):
        tr.run()


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_flagging():
    mon = StragglerMonitor(min_samples=8, k_mad=4.0)
    rng = np.random.default_rng(0)
    for _ in range(16):
        times = {f"h{i}": 1.0 + rng.normal(0, 0.01) for i in range(8)}
        times["h3"] = 1.8 + rng.normal(0, 0.01)   # consistent straggler
        mon.record_step(times)
    rep = mon.report()
    assert rep.flagged == ["h3"]
    assert rep.slowest[0][0] == "h3"
    assert mon.should_evict() == ["h3"]


def test_straggler_no_false_positives():
    mon = StragglerMonitor(min_samples=8)
    rng = np.random.default_rng(1)
    for _ in range(16):
        mon.record_step({f"h{i}": 1.0 + rng.normal(0, 0.02)
                         for i in range(8)})
    assert mon.report().flagged == []


# ---------------------------------------------------------------------------
# data pipeline: determinism + elastic resharding
# ---------------------------------------------------------------------------

def test_data_deterministic_and_step_keyed():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=8)
    d1, d2 = SyntheticLMDataset(cfg), SyntheticLMDataset(cfg)
    b1, b2 = d1.global_batch(3), d2.global_batch(3)
    assert_allclose(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(d1.global_batch(4)["tokens"]),
                              np.asarray(b1["tokens"]))


def test_elastic_reshard_preserves_global_batch():
    """4 hosts' shards and 2 hosts' shards tile the same global batch."""
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=8)
    ds = SyntheticLMDataset(cfg)
    g = ds.global_batch(11)
    four = np.concatenate([np.asarray(ds.host_batch(11, i, 4)["tokens"])
                           for i in range(4)])
    two = np.concatenate([np.asarray(ds.host_batch(11, i, 2)["tokens"])
                          for i in range(2)])
    assert_allclose(four, np.asarray(g["tokens"]))
    assert_allclose(two, np.asarray(g["tokens"]))


def test_targets_shift_by_one():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=2)
    b = SyntheticLMDataset(cfg).global_batch(0)
    assert_allclose(np.asarray(b["tokens"][:, 1:]),
                    np.asarray(b["targets"][:, :-1]))


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-1.3b",
                                  "zamba2-1.2b"])
def test_serving_continuous_batching(arch):
    cfg = smoke_variant(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(
        max_slots=2, max_len=64, sampler=SamplerConfig(temperature=0.0)))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5 + i),
                    max_tokens=4) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_serving_greedy_is_deterministic():
    cfg = smoke_variant(get_arch("granite-3-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, ServeConfig(
            max_slots=1, max_len=64, sampler=SamplerConfig(temperature=0.0)))
        done = eng.run([Request(rid=0, prompt=np.arange(8) % cfg.vocab,
                                max_tokens=6)])
        outs.append(done[0].out_tokens)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# schedules + compression
# ---------------------------------------------------------------------------

def test_schedule_shapes():
    cfg = ScheduleConfig(kind="cosine", warmup_steps=10, total_steps=100,
                         min_ratio=0.1)
    assert float(lr_scale(cfg, 0)) == 0.0
    assert abs(float(lr_scale(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(lr_scale(cfg, 100)) - 0.1) < 1e-6
    mid = float(lr_scale(cfg, 55))
    assert 0.1 < mid < 1.0


def test_grad_compression_error_feedback_converges():
    """Sum of compressed grads over steps -> true sum (error feedback)."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (32, 32))}
    ef = init_error_feedback(grads)
    acc = jnp.zeros((32, 32))
    for i in range(50):
        q, ef = compress_grads_with_feedback(grads, ef)
        acc = acc + decompress_grads(q, grads)["w"]
    true = grads["w"] * 50
    rel = float(jnp.linalg.norm(acc - true) / jnp.linalg.norm(true))
    assert rel < 0.01, rel


def test_compression_is_4x_smaller():
    g = jnp.ones((1024,), jnp.float32)
    from repro.optim.compression import quantize_leaf
    q, scale = quantize_leaf(g)
    assert q.dtype == jnp.int8
    assert q.nbytes * 4 == g.nbytes
