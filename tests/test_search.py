"""Offline schedule search (repro.search): determinism, versioned
artifacts, and the zero-sweep consumption contract.

What must hold:

  * ``workload`` — the host-side mirror of the serving scheduler's batch
    formation — dispatches exactly the (bucket, resolution) key set the
    real serving replay does (``serving_bench.EXPECTED_SMOKE_KEYS``);
  * ``search`` is bit-for-bit deterministic under a fixed seed, and the
    searched objective never exceeds the hand-default one;
  * ``ScheduleArtifact`` round-trips through JSON, and a schema-version,
    config-hash or precision mismatch raises a typed ``ArtifactError``
    instead of silently serving a stale schedule;
  * an artifact-warm ``ExecutorCache``/``VisionEngine`` performs ZERO
    autotune sweeps and reproduces the searched plan decision for
    decision;
  * ``plan_program(overrides=...)`` honors injected routing/blocks
    verbatim without consulting the tuner;
  * the autotune disk cache is schema-versioned: unversioned or
    wrong-version files are rejected with a warning, not adopted.
"""
import dataclasses
import json
import os
import sys

import jax
import pytest

# the trace fixture's generator lives in benchmarks/ (repo root, not src)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.common.errors import ArtifactError
from repro.core.accelerator_model import HwConfig, analyze_program, \
    site_breakdown
from repro.core.efficientvit import B1_SMOKE, init_efficientvit
from repro.core.fusion import SiteOverride, plan_program
from repro.core.program import lower
from repro.kernels import autotune as at
from repro.search import (ARTIFACT_SCHEMA, TRACE_SCHEMA, ScheduleArtifact,
                          config_hash, evaluate, key_cycles, load_trace,
                          save_trace, search, sweep_blocks,
                          trace_fingerprint, workload)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "trace_smoke.json")
SMOKE_SPEC = dict(buckets=(1, 2, 4), deadline_ms=40.0)


@pytest.fixture(scope="module")
def params():
    return init_efficientvit(jax.random.PRNGKey(0), B1_SMOKE)


@pytest.fixture(scope="module")
def searched(params, tmp_path_factory):
    """One real search run against the committed fixture trace, under an
    isolated tuner cache (module-scoped: the search is the expensive
    part, the consumption tests share its artifact)."""
    td = tmp_path_factory.mktemp("search_at")
    old = os.environ.get("REPRO_AUTOTUNE_CACHE")
    os.environ["REPRO_AUTOTUNE_CACHE"] = str(td / "at.json")
    at.clear_memory_cache()
    try:
        trace = load_trace(FIXTURE)
        art = search(B1_SMOKE, params, trace,
                     buckets=SMOKE_SPEC["buckets"], precision="auto",
                     deadline_ms=SMOKE_SPEC["deadline_ms"], seed=0,
                     iters=16)
        yield trace, art
    finally:
        if old is None:
            os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
        else:
            os.environ["REPRO_AUTOTUNE_CACHE"] = old
        at.clear_memory_cache()


# -- traces ---------------------------------------------------------------

def test_trace_roundtrip(tmp_path):
    trace = [(0.0, 64), (0.001, 32), (0.5, 64)]
    path = str(tmp_path / "t.json")
    fp = save_trace(path, trace, spec={"buckets": (1, 2), "note": "x"})
    assert fp == trace_fingerprint(trace)
    assert load_trace(path) == [(0.0, 64), (0.001, 32), (0.5, 64)]
    # fingerprint is content-addressed: same requests, same hash
    assert trace_fingerprint(load_trace(path)) == fp


def test_trace_schema_rejected(tmp_path):
    path = str(tmp_path / "t.json")
    save_trace(path, [(0.0, 64)])
    doc = json.load(open(path))
    doc["schema"] = TRACE_SCHEMA + 1
    json.dump(doc, open(path, "w"))
    with pytest.raises(ArtifactError, match="schema"):
        load_trace(path)
    json.dump({"schema": TRACE_SCHEMA, "requests": [["bad"]]},
              open(path, "w"))
    with pytest.raises(ArtifactError, match="malformed"):
        load_trace(path)
    with pytest.raises(ArtifactError, match="unreadable"):
        load_trace(str(tmp_path / "missing.json"))


def test_fixture_matches_generator():
    """The committed fixture IS serving_bench's smoke trace — if either
    drifts, re-record with ``serving_bench --smoke --record-trace``."""
    from benchmarks.serving_bench import SMOKE, make_trace
    assert trace_fingerprint(load_trace(FIXTURE)) \
        == trace_fingerprint(make_trace(SMOKE, seed=0))


def test_workload_matches_serving_keys():
    """The host-side workload model dispatches exactly the executor keys
    the real serving replay is pinned to (the drift gate both share)."""
    from benchmarks.serving_bench import EXPECTED_SMOKE_KEYS
    wl = workload(load_trace(FIXTURE), SMOKE_SPEC["buckets"],
                  deadline_ms=SMOKE_SPEC["deadline_ms"])
    assert set(wl) == EXPECTED_SMOKE_KEYS
    assert all(n > 0 for n in wl.values())
    # every request is dispatched somewhere (capacity >= 12 arrivals)
    assert sum(b * n for (b, _), n in wl.items()) >= 12


# -- cost surface ---------------------------------------------------------

def test_sweep_blocks_deterministic_and_in_candidates(params):
    kw = dict(batch=1, resolution=64, precision="auto")
    best = sweep_blocks(B1_SMOKE, params, **kw)
    assert best, "smoke config has fused sites with block candidates"
    assert best == sweep_blocks(B1_SMOKE, params, **kw)
    from repro.kernels.registry import get_kernel
    program = lower(B1_SMOKE, batch=1, image_size=64)
    plan = plan_program(program, params, autotune=False)
    for site in program.fusible():
        if site.name not in best:
            continue
        impl = get_kernel(site.kind, plan.get(site.name).precision)
        assert best[site.name] in [dict(c) for c in impl.candidates(site)]


def test_key_cycles_demotion_costs_launches(params):
    """In-model, demoting every site must never be free: the per-launch
    overhead charges the extra dispatches the reference path makes."""
    base = key_cycles(B1_SMOKE, params, 4, 64, precision="auto")
    names = frozenset(
        s.name for s in lower(B1_SMOKE, batch=4, image_size=64).fusible())
    demoted = key_cycles(B1_SMOKE, params, 4, 64, precision="auto",
                         demoted=names)
    assert base > 0 and demoted > base


# -- the search -----------------------------------------------------------

def test_search_deterministic(params, tmp_autotune_cache):
    trace = load_trace(FIXTURE)
    dicts = []
    for _ in range(2):
        at.clear_memory_cache()
        dicts.append(search(
            B1_SMOKE, params, trace, buckets=SMOKE_SPEC["buckets"],
            precision="auto", deadline_ms=SMOKE_SPEC["deadline_ms"],
            seed=0, iters=12).to_dict())
    assert dicts[0] == dicts[1]


def test_search_beats_default_and_stamps_provenance(searched):
    trace, art = searched
    assert art.objective <= art.default_objective
    assert art.schema == ARTIFACT_SCHEMA
    assert art.config_hash == config_hash(B1_SMOKE)
    assert art.trace_fingerprint == trace_fingerprint(trace)
    assert art.config_name == B1_SMOKE.name
    # every (bucket, resolution) shape is materialized
    assert set(art.entries) == {f"{b}x{r}" for b in art.buckets
                                for r in art.resolutions}
    for decisions in art.entries.values():
        assert decisions and all("name" in d and "fused" in d
                                 for d in decisions)


# -- artifacts ------------------------------------------------------------

def test_artifact_roundtrip(searched, tmp_path):
    _, art = searched
    path = str(tmp_path / "sched.json")
    art.save(path)
    loaded = ScheduleArtifact.load(path)
    assert loaded.to_dict() == art.to_dict()
    assert loaded.validate_for(B1_SMOKE, "auto") is loaded


def test_artifact_schema_mismatch_rejected(searched, tmp_path):
    _, art = searched
    doc = art.to_dict()
    doc["schema"] = ARTIFACT_SCHEMA + 1
    with pytest.raises(ArtifactError, match="schema"):
        ScheduleArtifact.from_dict(doc)
    path = str(tmp_path / "bad.json")
    json.dump(doc, open(path, "w"))
    with pytest.raises(ArtifactError, match="schema"):
        ScheduleArtifact.load(path)
    with pytest.raises(ArtifactError, match="unreadable"):
        ScheduleArtifact.load(str(tmp_path / "missing.json"))


def test_artifact_config_and_precision_mismatch_rejected(searched):
    _, art = searched
    other = dataclasses.replace(B1_SMOKE, image_size=96)
    assert config_hash(other) != config_hash(B1_SMOKE)
    with pytest.raises(ArtifactError, match="config"):
        art.validate_for(other, "auto")
    with pytest.raises(ArtifactError, match="precision"):
        art.validate_for(B1_SMOKE, "int8")


def test_artifact_uncovered_shape_returns_none(searched):
    _, art = searched
    assert art.overrides_for(3, 64) is None          # never a bucket
    assert art.overrides_for(max(art.buckets), 640) is None
    b, r = art.buckets[0], art.resolutions[0]
    ov = art.overrides_for(b, r)
    assert ov and all(isinstance(v, SiteOverride) for v in ov.values())


# -- consumption: zero-sweep cold start -----------------------------------

def test_artifact_warm_cache_zero_sweeps_and_reproduces(
        searched, tmp_autotune_cache, params):
    from repro.serving.executors import ExecutorCache
    _, art = searched
    sweeps0 = at.SWEEP_COUNT
    cache = ExecutorCache(params, B1_SMOKE, buckets=(1, 2, 4),
                          precision="auto", autotune=True, artifact=art)
    # the searched bucket set replaces the constructor's
    assert cache.buckets == art.buckets
    for b in art.buckets:
        for res in art.resolutions:
            ex = cache.get(b, res)
            got = [d.to_dict() for d in ex.plan.decisions.values()]
            assert got == art.decisions_for(b, res), (b, res)
    assert at.SWEEP_COUNT == sweeps0, \
        "artifact-warm planning must not run autotune sweeps"


def test_executor_cache_rejects_stale_artifact(searched, params):
    from repro.serving.executors import ExecutorCache
    _, art = searched
    with pytest.raises(ArtifactError, match="precision"):
        ExecutorCache(params, B1_SMOKE, precision="int8", artifact=art)


def test_vision_engine_adopts_artifact(searched, tmp_autotune_cache,
                                       params, tmp_path):
    from repro.serving.vision import VisionEngine, VisionServeConfig
    _, art = searched
    path = str(tmp_path / "sched.json")
    art.save(path)
    sweeps0 = at.SWEEP_COUNT
    engine = VisionEngine(params, B1_SMOKE, VisionServeConfig(
        microbatch=8, precision="auto", artifact=path))
    assert at.SWEEP_COUNT == sweeps0
    assert engine.microbatch == max(art.buckets)
    assert engine.cache.buckets == art.buckets
    assert engine.artifact is not None
    assert engine.plan is not None     # primary executor planned eagerly


# -- the injection lever: plan_program(overrides=...) ---------------------

def test_override_demotes_site_with_search_reason(params):
    program = lower(B1_SMOKE, batch=1, image_size=64)
    name = program.fusible()[0].name
    plan = plan_program(program, params, autotune=False,
                        overrides={name: SiteOverride(fused=False)})
    d = plan.get(name)
    assert d is not None and not d.fused and d.reason == "search"


def test_override_blocks_pinned_without_tuner(params, tmp_autotune_cache):
    """Frozen blocks are honored verbatim and the tuner is never
    consulted even with ``autotune=True`` — the zero-sweep guarantee at
    the planner level."""
    program = lower(B1_SMOKE, batch=1, image_size=64)
    base = plan_program(program, params, autotune=False)
    overrides = {n: SiteOverride.from_decision(d)
                 for n, d in base.decisions.items()}
    sweeps0 = at.SWEEP_COUNT
    pinned = plan_program(program, params, autotune=True,
                          overrides=overrides)
    assert at.SWEEP_COUNT == sweeps0
    for n, d in base.decisions.items():
        p = pinned.get(n)
        assert (p.fused, p.precision, dict(p.blocks)) \
            == (d.fused, d.precision, dict(d.blocks)), n


# -- autotune cache schema versioning -------------------------------------

def test_autotune_cache_rejects_unversioned_file(tmp_autotune_cache):
    path = at.cache_path()
    json.dump({"mbconv|b=1": {"block_f": 64}}, open(path, "w"))
    at.clear_memory_cache()
    with pytest.warns(RuntimeWarning, match="schema version None"):
        assert at.export_entries() == {}


def test_autotune_cache_rejects_wrong_version(tmp_autotune_cache):
    path = at.cache_path()
    json.dump({at._SCHEMA_KEY: {"version": at.AUTOTUNE_SCHEMA + 1},
               "mbconv|b=1": {"block_f": 64}}, open(path, "w"))
    at.clear_memory_cache()
    with pytest.warns(RuntimeWarning, match="schema version"):
        assert at.export_entries() == {}


def test_autotune_cache_accepts_current_version(tmp_autotune_cache):
    path = at.cache_path()
    json.dump({at._SCHEMA_KEY: {"version": at.AUTOTUNE_SCHEMA},
               "mbconv|b=1": {"block_f": 64}}, open(path, "w"))
    at.clear_memory_cache()
    assert at.export_entries() == {"mbconv|b=1": {"block_f": 64}}


def test_autotune_import_export_roundtrip(tmp_autotune_cache):
    # the schema row is metadata, never an entry: import filters it
    n = at.import_entries({"k|b=1": {"block_n": 128},
                           at._SCHEMA_KEY: {"version": 99},
                           "bad": "not-a-dict"}, persist=True)
    assert n == 1
    at.clear_memory_cache()       # force the disk round-trip
    assert at.export_entries() == {"k|b=1": {"block_n": 128}}
    # the persisted file is stamped at the CURRENT schema
    assert json.load(open(at.cache_path()))[at._SCHEMA_KEY] \
        == {"version": at.AUTOTUNE_SCHEMA}
    # and a seeded entry is an autotune() hit: no sweep
    sweeps0 = at.SWEEP_COUNT
    choice = at.autotune("k", ("b=1",), [{"block_n": 64}],
                         bench=lambda c: None)
    assert choice == {"block_n": 128} and at.SWEEP_COUNT == sweeps0


# -- the per-site breakdown (the search's evaluator surface) --------------

def test_site_breakdown_matches_analyze_program(params):
    program = lower(B1_SMOKE)
    hw = HwConfig()
    rep, _stages, _sched = analyze_program(program, hw)
    rows = site_breakdown(program, hw)     # plan=None, int8 default
    assert sum(r["macs"] for r in rows) == rep.total_macs
    assert sum(r["cycles"] for r in rows) \
        == pytest.approx(rep.total_cycles, rel=1e-9)
    assert sum(r["dram_bytes"] for r in rows) \
        == pytest.approx(rep.dram_bytes, rel=1e-9)
    # machine-readable: every row JSON-serializes with the full schema
    for r in rows:
        assert {"site", "kind", "stage", "fused", "precision", "reason",
                "blocks", "launches", "macs", "compute_cycles",
                "dram_bytes", "cycles"} <= set(r)
    json.dumps(rows)
    json.dumps(rep.to_dict())


def test_site_breakdown_under_plan_reports_decisions(params):
    program = lower(B1_SMOKE, batch=1, image_size=64)
    name = program.fusible()[0].name
    plan = plan_program(program, params, autotune=False,
                        overrides={name: SiteOverride(fused=False)})
    rows = {r["site"]: r
            for r in site_breakdown(program, hw=HwConfig(), plan=plan,
                                    default_precision="fp")}
    row = rows[name]
    assert not row["fused"] and row["reason"] == "search"
    # the reference path launches every op separately: more launches
    # than any fused row of the same kind
    fused_rows = [r for r in rows.values()
                  if r["fused"] and r["kind"] == row["kind"]]
    if fused_rows:
        assert row["launches"] > min(r["launches"] for r in fused_rows)
