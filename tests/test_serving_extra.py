"""Serving edge cases + sampler behaviour + straggler->elastic handshake."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.models.registry import build_model
from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.serving.sampler import SamplerConfig, sample


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

def test_greedy_sampler_is_argmax():
    logits = jnp.asarray([[1.0, 5.0, 2.0], [0.1, 0.0, 3.0]])
    out = sample(logits, jax.random.PRNGKey(0), SamplerConfig())
    assert out.tolist() == [1, 2]


def test_top_k_restricts_support():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0, 10.0]] * 64)
    cfg = SamplerConfig(temperature=1.0, top_k=2)
    toks = np.asarray(sample(logits, key, cfg))
    assert set(toks.tolist()) <= {3, 4}


def test_top_p_restricts_support():
    key = jax.random.PRNGKey(1)
    # one dominant token (p ~ 0.94) -> top_p=0.9 keeps only it
    logits = jnp.asarray([[0.0, 0.0, 0.0, 6.0]] * 32)
    cfg = SamplerConfig(temperature=1.0, top_p=0.9)
    toks = np.asarray(sample(logits, key, cfg))
    assert set(toks.tolist()) == {3}


def test_temperature_zero_deterministic():
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 100))
    a = sample(logits, jax.random.PRNGKey(3), SamplerConfig())
    b = sample(logits, jax.random.PRNGKey(4), SamplerConfig())
    assert (np.asarray(a) == np.asarray(b)).all()


# ---------------------------------------------------------------------------
# engine edge cases
# ---------------------------------------------------------------------------

def _engine(slots=2, max_len=64, eos=-1):
    cfg = smoke_variant(get_arch("granite-3-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(cfg, params, ServeConfig(
        max_slots=slots, max_len=max_len, eos_token=eos,
        sampler=SamplerConfig(temperature=0.0)))


def test_engine_rejects_when_full():
    cfg, eng = _engine(slots=1)
    assert eng.admit(Request(rid=0, prompt=np.arange(4), max_tokens=8))
    assert not eng.admit(Request(rid=1, prompt=np.arange(4), max_tokens=8))


def test_engine_slot_reuse_after_finish():
    cfg, eng = _engine(slots=1)
    done = eng.run([Request(rid=i, prompt=np.arange(3 + i), max_tokens=3)
                    for i in range(3)])
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out_tokens) == 3 for r in done)


def test_engine_eos_stops_early():
    cfg, eng = _engine(slots=1, eos=0)
    done = eng.run([Request(rid=0, prompt=np.arange(4), max_tokens=32)])
    r = done[0]
    # either hit eos (last token 0) or exhausted the budget
    assert r.out_tokens[-1] == 0 or len(r.out_tokens) == 32


def test_ragged_prompts_match_solo_decode():
    """Two ragged requests batched == each served alone (greedy)."""
    cfg, eng = _engine(slots=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 5), rng.integers(0, cfg.vocab, 13)]
    batched = eng.run([Request(rid=i, prompt=p, max_tokens=5)
                       for i, p in enumerate(prompts)])
    batched = {r.rid: r.out_tokens for r in batched}
    for i, p in enumerate(prompts):
        cfg2, solo_eng = _engine(slots=1)
        solo = solo_eng.run([Request(rid=0, prompt=p, max_tokens=5)])
        assert solo[0].out_tokens == batched[i], i


# ---------------------------------------------------------------------------
# straggler -> elastic handshake
# ---------------------------------------------------------------------------

def test_straggler_triggers_elastic_remesh():
    """Flagged host -> drop it -> reshard state onto survivors -> state
    values preserved bit-exactly."""
    from repro.runtime.straggler import StragglerMonitor
    from repro.runtime.elastic import replicate_tree

    mon = StragglerMonitor(min_samples=8)
    rng = np.random.default_rng(0)
    hosts = [f"h{i}" for i in range(4)]
    for _ in range(12):
        times = {h: 1.0 + rng.normal(0, 0.01) for h in hosts}
        times["h2"] = 2.5
        mon.record_step(times)
    evict = mon.should_evict()
    assert evict == ["h2"]

    # single-device container: model the re-mesh as replicate-on-survivors
    survivors = [h for h in hosts if h not in evict]
    assert len(survivors) == 3
    state = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 8))}
    mesh = jax.make_mesh((1,), ("data",))
    out = replicate_tree(state, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(state["w"]))
