"""Serving runtime: executor cache, micro-batching scheduler, telemetry,
multi-resolution lowering, and the autotune cache-key audit.

The contracts under test:
  * ``lower`` is resolution/batch-parameterized with geometry validated
    at lowering time, and ``execute`` over any (batch, resolution) pair
    agrees with the reference forward in both precisions;
  * ``ExecutorCache`` compiles lazily, serves LRU, evicts at capacity,
    and shares fusion-plan block choices across batch buckets at the
    same resolution (``plan_program(..., reuse=)``);
  * the scheduler groups same-resolution requests into the largest
    ready bucket, routes ragged tails to the smallest covering bucket
    (zero pad waste when the tail IS a bucket), and flushes on deadline;
  * autotune persistent-cache keys carry batch + spatial dims, so
    bucketed shapes cannot collide on stale block choices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.efficientvit import B1, B1_SMOKE, efficientvit, init_efficientvit
from repro.core.fusion import plan_program
from repro.core.program import execute, lower
from repro.core.quantization import quantize_efficientvit
from repro.serving.executors import ExecutorCache, ExecutorKey
from repro.serving.scheduler import (
    BucketedPolicy, FixedMicrobatchPolicy, ManualClock, MicroBatchScheduler,
    Request)
from repro.serving.telemetry import Telemetry, percentile


@pytest.fixture
def smoke_params():
    return init_efficientvit(jax.random.PRNGKey(0), B1_SMOKE)


def _images(n, res, seed=1):
    return np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (n, res, res, 3)), np.float32)


# ---------------------------------------------------------------------------
# multi-resolution lowering + execute parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("res", [192, 224, 256])
@pytest.mark.parametrize("batch", [1, 4, 8])
def test_lower_multi_resolution_geometry(res, batch):
    """B1 lowers at serving resolutions/batches with a consistent shape
    chain (validated inside lower) and the expected head geometry."""
    program = lower(B1, batch=batch, image_size=res)
    assert program.batch == batch and program.image_size == res
    r = res // 32
    gap = program.site("head.gap")
    assert gap.in_shape == (batch, r, r, B1.head_widths[0])
    assert program.sites[-1].out_shape == (batch, B1.num_classes)
    # every site consumes its predecessor's output (chain re-check)
    for prev, cur in zip(program.sites, program.sites[1:]):
        assert cur.in_shape == prev.out_shape, (prev.name, cur.name)


def test_lower_rejects_bad_geometry():
    with pytest.raises(ValueError, match="multiples of 32"):
        lower(B1, image_size=200)
    with pytest.raises(ValueError, match="batch"):
        lower(B1, batch=0)


@pytest.mark.parametrize("res,batch", [(32, 1), (32, 4), (64, 2), (96, 1)])
def test_multi_resolution_reference_is_the_forward(smoke_params, res, batch):
    """plan=None execute == the efficientvit shim, bit-for-bit, at every
    (resolution, batch) pair."""
    x = _images(batch, res)
    program = lower(B1_SMOKE, batch=batch, image_size=res)
    ref = execute(program, smoke_params, x)
    shim = efficientvit(smoke_params, x, B1_SMOKE)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(shim))


@pytest.mark.parametrize("res,batch", [(32, 4), (64, 2), (96, 1)])
def test_multi_resolution_fused_parity_fp(smoke_params, res, batch,
                                          tmp_autotune_cache):
    x = _images(batch, res)
    program = lower(B1_SMOKE, batch=batch, image_size=res)
    plan = plan_program(program, smoke_params, autotune=False)
    ref = execute(program, smoke_params, x)
    fus = execute(program, smoke_params, x, plan=plan)
    assert_allclose(np.asarray(fus), np.asarray(ref), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("res,batch", [(32, 1), (64, 1), (32, 2)])
def test_multi_resolution_fused_parity_int8(smoke_params, res, batch,
                                            tmp_autotune_cache):
    """Batch 1: int8-fused is bit-exact vs the int8 reference chain (the
    in-kernel requant scales coincide); batch > 1 within quantization
    noise with the top-1 label preserved."""
    qparams = quantize_efficientvit(smoke_params)
    x = _images(batch, res)
    program = lower(B1_SMOKE, batch=batch, image_size=res)
    plan = plan_program(program, qparams, autotune=False)
    assert all(d.precision == "int8"
               for d in plan.decisions.values() if d.fused)
    ref = execute(program, qparams, x)
    fus = execute(program, qparams, x, plan=plan)
    if batch == 1:
        np.testing.assert_array_equal(np.asarray(fus), np.asarray(ref))
    else:
        assert bool((jnp.argmax(ref, -1) == jnp.argmax(fus, -1)).all())
        assert float(jnp.max(jnp.abs(ref - fus))) < 1e-2


def test_plan_vmem_fallback_at_large_resolution(tmp_autotune_cache):
    """B1 @384 fp: the early high-resolution MBConvs used to blow the
    8 MB VMEM budget and demote to the reference path with reason
    "vmem".  Spatially-banded super-sites retire that fallback — the
    grouping pass rescues the demoted S1 pair with a row-banded group,
    so the 384 plan demotes NOTHING in either precision, and the fused
    forward still matches the reference at 384.  @256 nothing falls
    back either."""
    params = init_efficientvit(jax.random.PRNGKey(5), B1)
    qparams = quantize_efficientvit(params)

    p384 = lower(B1, batch=1, image_size=384)
    fp_plan = plan_program(p384, params, autotune=False)
    vmem_sites = {d.name for d in fp_plan.decisions.values()
                  if d.reason == "vmem"}
    assert vmem_sites == set(), vmem_sites
    assert all(d.fused for d in fp_plan.decisions.values())
    # the rescue is a banded super-site over the former demotion pair
    assert any(set(g.members) == {"S1.mb0", "S1.mb1"}
               and g.blocks.get("block_rows")
               for g in fp_plan.groups.values()), fp_plan.groups
    q_plan = plan_program(p384, qparams, autotune=False)
    assert not any(d.reason == "vmem" for d in q_plan.decisions.values())

    # fused parity at the rescued resolution (the banding is exact: the
    # band boundary only splits rows the 1x1 stages treat pointwise)
    x384 = _images(1, 384)
    ref = execute(p384, params, x384)
    fus = execute(p384, params, x384, plan=fp_plan)
    assert_allclose(np.asarray(fus), np.asarray(ref), rtol=1e-3, atol=1e-3)

    p256 = lower(B1, batch=1, image_size=256)
    for tree in (params, qparams):
        plan = plan_program(p256, tree, autotune=False)
        assert all(d.fused for d in plan.decisions.values()), \
            {d.name: d.reason for d in plan.decisions.values() if not d.fused}


# ---------------------------------------------------------------------------
# executor cache
# ---------------------------------------------------------------------------

def test_executor_cache_hit_miss_eviction(smoke_params, tmp_autotune_cache):
    cache = ExecutorCache(smoke_params, B1_SMOKE, buckets=(1, 2),
                          autotune=False, capacity=2)
    a = cache.get(1, 64)
    assert cache.get(1, 64) is a                      # hit
    cache.get(2, 64)
    assert cache.telemetry.counters["executor_miss"] == 2
    assert cache.telemetry.counters["executor_hit"] == 1
    cache.get(1, 32)                                  # evicts LRU (1, 64)
    assert cache.telemetry.counters["executor_evicted"] == 1
    assert ExecutorKey(1, 64, "auto") not in cache.keys()
    assert len(cache) == 2
    b = cache.get(1, 64)                              # rebuilt, not the
    assert b is not a                                 # evicted object


def test_executor_cache_plan_reuse_across_buckets(smoke_params,
                                                  tmp_autotune_cache):
    """The first plan at a resolution donates its tuned blocks to every
    later bucket at that resolution; another resolution tunes fresh."""
    cache = ExecutorCache(smoke_params, B1_SMOKE, buckets=(1, 2, 4),
                          autotune=False)
    donor = cache.get(4, 64)
    assert not any(d.reused for d in donor.plan.decisions.values())
    ex1 = cache.get(1, 64)
    fused = [d for d in ex1.plan.decisions.values() if d.fused]
    assert fused and all(d.reused for d in fused)
    for name, d in ex1.plan.decisions.items():
        if d.fused:
            assert d.blocks == donor.plan.decisions[name].blocks
    assert cache.telemetry.counters["plan_sites_reused"] == len(fused)
    other = cache.get(1, 32)                          # new resolution:
    assert not any(d.reused for d in other.plan.decisions.values())


def test_bucket_cover(smoke_params):
    cache = ExecutorCache(smoke_params, B1_SMOKE, buckets=(1, 2, 4),
                          use_plan=False)
    assert cache.bucket_for(1) == 1 and cache.bucket_for(3) == 4
    assert cache.bucket_for(9) == 4          # caller splits
    assert cache.chunks_for(7) == [4, 4]     # tail 3 -> smallest bucket >= 3
    assert cache.chunks_for(5) == [4, 1]
    assert cache.chunks_for(4) == [4]
    assert cache.chunks_for(3) == [4]        # 3 pads into one 4-bucket


def test_executor_warmup_compiles_working_set(smoke_params,
                                              tmp_autotune_cache):
    cache = ExecutorCache(smoke_params, B1_SMOKE, buckets=(1, 2),
                          autotune=False)
    cache.warmup((64,))
    assert {(k.batch, k.resolution) for k in cache.keys()} == \
        {(1, 64), (2, 64)}
    assert all(cache.get(b, 64).warmed for b in (1, 2))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _scheduler(params, buckets=(1, 2, 4), policy=None, clock=None,
               precision="auto"):
    cache = ExecutorCache(params, B1_SMOKE, buckets=buckets,
                          precision=precision, autotune=False)
    return MicroBatchScheduler(cache, params, policy=policy, clock=clock)


def test_scheduler_bucketed_tail_no_padding(smoke_params,
                                            tmp_autotune_cache):
    """5 same-resolution requests over buckets (1,2,4) dispatch as a
    full 4-bucket plus a 1-bucket tail — zero padded slots (the fixed
    policy pads 3) — and match the reference forward."""
    sched = _scheduler(smoke_params)
    imgs = _images(5, 32)
    out = sched.serve([Request(rid=i, image=imgs[i]) for i in range(5)])
    tel = sched.telemetry
    assert tel.total("padded") == 0 and tel.total("samples") == 5
    assert {key[0] for key in tel.buckets} == {1, 4}
    ref = efficientvit(smoke_params, imgs, B1_SMOKE)
    assert_allclose(out, np.asarray(ref), rtol=1e-3, atol=1e-3)


def test_scheduler_only_dispatches_full_buckets_until_due(smoke_params,
                                                          tmp_autotune_cache):
    clock = ManualClock()
    sched = _scheduler(smoke_params, clock=clock)
    imgs = _images(5, 32)
    for i in range(5):
        sched.submit(Request(rid=i, image=imgs[i]))
    assert sched.step() == 4                 # one full 4-bucket forms
    assert sched.queue_depth(32) == 1        # tail waits (no deadline)
    assert sched.step() == 0
    assert sched.step(drain=True) == 1       # drain flushes to bucket 1
    sched.finalize()
    assert sched.telemetry.total("padded") == 0


def test_scheduler_deadline_flush(smoke_params, tmp_autotune_cache):
    clock = ManualClock()
    sched = _scheduler(smoke_params, clock=clock)
    sched.submit(Request(rid=0, image=_images(1, 32)[0], deadline_ms=10.0))
    assert sched.step() == 0                 # not due, bucket not full
    clock.advance(0.02)
    assert sched.step() == 1                 # deadline flushes the tail
    sched.finalize()
    (key,) = sched.telemetry.buckets
    assert key[0] == 1                       # smallest covering bucket


def test_scheduler_mixed_resolutions(smoke_params, tmp_autotune_cache):
    """Queues are per-resolution; logits come back in request order and
    match each resolution's reference forward."""
    sched = _scheduler(smoke_params, buckets=(1, 2))
    img32, img64 = _images(3, 32), _images(2, 64, seed=2)
    reqs = [Request(rid=0, image=img32[0]), Request(rid=1, image=img64[0]),
            Request(rid=2, image=img32[1]), Request(rid=3, image=img64[1]),
            Request(rid=4, image=img32[2])]
    out = sched.serve(reqs)
    assert out.shape == (5, B1_SMOKE.num_classes)
    ref32 = np.asarray(efficientvit(smoke_params, img32, B1_SMOKE))
    ref64 = np.asarray(efficientvit(smoke_params, img64, B1_SMOKE))
    assert_allclose(out[[0, 2, 4]], ref32, rtol=1e-3, atol=1e-3)
    assert_allclose(out[[1, 3]], ref64, rtol=1e-3, atol=1e-3)


def test_fixed_policy_pads_to_microbatch(smoke_params, tmp_autotune_cache):
    """The legacy baseline: 5 requests at microbatch 4 dispatch 4+4 with
    3 padded slots (vs 0 for the bucketed policy)."""
    sched = _scheduler(smoke_params, policy=FixedMicrobatchPolicy(4))
    imgs = _images(5, 32)
    sched.serve([Request(rid=i, image=imgs[i]) for i in range(5)])
    tel = sched.telemetry
    assert tel.total("padded") == 3
    assert tel.total("dispatches") == 2
    assert {key[0] for key in tel.buckets} == {4}


def test_bucketed_policy_formation():
    buckets = (1, 2, 4)
    p = BucketedPolicy()
    assert p.form(9, buckets, due=False) == [4, 4]
    assert p.form(9, buckets, due=True) == [4, 4, 1]
    assert p.form(3, buckets, due=False) == []
    assert p.form(3, buckets, due=True) == [4]
    f = FixedMicrobatchPolicy(4)
    assert f.form(9, buckets, due=False) == [4, 4]
    assert f.form(9, buckets, due=True) == [4, 4, 4]


# ---------------------------------------------------------------------------
# VisionEngine façade
# ---------------------------------------------------------------------------

def test_vision_engine_tail_routes_to_small_bucket(smoke_params,
                                                   tmp_autotune_cache):
    from repro.serving.vision import VisionEngine, VisionServeConfig
    eng = VisionEngine(smoke_params, B1_SMOKE,
                       VisionServeConfig(microbatch=4, autotune=False))
    imgs = _images(5, 64)
    logits = eng.logits(imgs)
    ref = efficientvit(smoke_params, imgs, B1_SMOKE)
    assert_allclose(np.asarray(logits), np.asarray(ref),
                    rtol=1e-3, atol=1e-3)
    used = {(k.batch, k.resolution) for k in eng.cache.keys()}
    assert (1, 64) in used                     # tail bucket, not pad-to-4
    assert eng.telemetry.total("padded") == 0


def test_vision_engine_fixed_policy_back_compat(smoke_params,
                                                tmp_autotune_cache):
    from repro.serving.vision import VisionEngine, VisionServeConfig
    eng = VisionEngine(smoke_params, B1_SMOKE,
                       VisionServeConfig(microbatch=2, autotune=False,
                                         policy="fixed"))
    imgs = _images(3, 64)
    logits = eng.logits(imgs)
    ref = efficientvit(smoke_params, imgs, B1_SMOKE)
    assert_allclose(np.asarray(logits), np.asarray(ref),
                    rtol=1e-3, atol=1e-3)
    assert {(k.batch, k.resolution) for k in eng.cache.keys()} == {(2, 64)}
    assert eng.telemetry.total("padded") == 1  # tail padded 1 -> 2


def test_vision_engine_quantized_serve(smoke_params, tmp_autotune_cache):
    """FIX8 serving through the scheduler: 3 requests over buckets (1,2)
    dispatch 2+1 and match the reference computed with the same
    chunking (dynamic act scales are per-dispatch)."""
    from repro.serving.vision import VisionEngine, VisionServeConfig
    eng = VisionEngine.quantized(
        smoke_params, B1_SMOKE,
        VisionServeConfig(microbatch=2, autotune=False))
    imgs = _images(3, 64)
    out = eng.serve([Request(rid=i, image=imgs[i]) for i in range(3)])
    ref = np.concatenate([
        np.asarray(efficientvit(eng.params, imgs[:2], B1_SMOKE)),
        np.asarray(efficientvit(eng.params, imgs[2:], B1_SMOKE))])
    # batch-1 chunk is bit-exact; the batch-2 chunk is within
    # quantization noise (in-kernel requant vs the reference chain)
    np.testing.assert_array_equal(out[2], ref[2])
    assert float(np.max(np.abs(out - ref))) < 1e-2
    assert bool((out.argmax(-1) == ref.argmax(-1)).all())
    assert all(k.precision == "int8" for k in eng.cache.keys())


# ---------------------------------------------------------------------------
# autotune cache-key audit (regression for bucket collisions)
# ---------------------------------------------------------------------------

def test_shape_key_carries_batch_and_spatial():
    from repro.kernels.autotune import shape_key
    base = dict(c=16, f=32, dtype="f32", backend="interp")
    k1 = shape_key(batch=1, spatial=(64, 64), **base)
    k2 = shape_key(batch=8, spatial=(64, 64), **base)
    k3 = shape_key(batch=1, spatial=(96, 96), **base)
    assert len({k1, k2, k3}) == 3
    assert "b=1" in k1 and "s=64x64" in k1
    assert "b=8" in k2 and "s=96x96" in k3
    # scalar spatial (token counts) normalizes
    assert "s=49" in shape_key(batch=4, spatial=49, d=16, dtype="f32",
                               backend="interp")


@pytest.mark.parametrize("kind", ["mbconv", "dsconv", "relu_attn"])
def test_tuner_keys_distinct_across_buckets(kind, monkeypatch,
                                            tmp_autotune_cache):
    """Every kernel family's tuner must key its persistent cache on
    batch AND spatial dims: two serving buckets differing only there
    may never share (or overwrite) a block choice."""
    captured = []

    def fake_autotune(k, key, candidates, bench=None):
        captured.append((k, tuple(key)))
        return dict(candidates[0])

    if kind == "mbconv":
        from repro.kernels.mbconv import ops
        monkeypatch.setattr(ops, "autotune", fake_autotune)
        ops.tune_block_f((1, 64, 64, 8), 32, 16, allow_sweep=False)
        ops.tune_block_f((8, 64, 64, 8), 32, 16, allow_sweep=False)
        ops.tune_block_f((1, 96, 96, 8), 32, 16, allow_sweep=False)
    elif kind == "dsconv":
        from repro.kernels.dsconv import ops
        monkeypatch.setattr(ops, "autotune", fake_autotune)
        ops.tune_block_f((1, 64, 64, 8), 16, allow_sweep=False)
        ops.tune_block_f((8, 64, 64, 8), 16, allow_sweep=False)
        ops.tune_block_f((1, 96, 96, 8), 16, allow_sweep=False)
    else:
        from repro.kernels.relu_attn import ops
        monkeypatch.setattr(ops, "autotune", fake_autotune)
        ops.tune_block_n(2, 256, 16, allow_sweep=False)    # batch bucket 1
        ops.tune_block_n(16, 256, 16, allow_sweep=False)   # batch bucket 8
        ops.tune_block_n(2, 576, 16, allow_sweep=False)    # other resolution
    keys = [key for _, key in captured]
    assert len(set(keys)) == 3, keys
    for key in keys:
        assert any(p.startswith("b=") for p in key), key
        assert any(p.startswith("s=") for p in key), key


def test_dsconv_tune_reads_persistent_cache(tmp_autotune_cache):
    """dsconv now tunes for real: a seeded cache entry under the new
    batch+spatial key is honored instead of the old hardcoded 128."""
    from repro.kernels import autotune as at
    from repro.kernels.dsconv.ops import tune_block_f
    key = at.shape_key(batch=2, spatial=(64, 64), c=8, f=8, stride=1,
                       dtype="f32", backend="interp")
    at._MEM[at._key("dsconv", key)] = {"block_f": 256}
    assert tune_block_f((2, 64, 64, 8), 8, allow_sweep=False,
                        interpret=True) == 256
    # a different batch bucket misses that entry -> heuristic first
    # candidate (64), NOT the batch-2 choice: no cross-bucket collision
    assert tune_block_f((4, 64, 64, 8), 8, allow_sweep=False,
                        interpret=True) == 64
    at.clear_memory_cache()


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_bucket_math_and_table():
    tel = Telemetry()
    key = (4, 224, "fp")
    tel.record_dispatch(key, 4, 4, queue_depth=2, wait_ms=[1.0, 2.0])
    tel.record_dispatch(key, 1, 4, queue_depth=0, wait_ms=[8.0])
    tel.record_latency(key, [5.0, 6.0])
    b = tel.bucket(key)
    assert b.dispatches == 2 and b.samples == 5 and b.padded == 3
    assert b.occupancy == pytest.approx(5 / 8)
    snap = tel.snapshot()
    assert snap["padded_total"] == 3 and snap["samples_total"] == 5
    assert snap["buckets"]["4/224/fp"]["wait_ms_p50"] == 2.0
    table = tel.table()
    assert "4x224xfp" in table and "TOTAL" in table
    assert percentile([], 0.5) != percentile([], 0.5)  # nan on empty
    assert percentile([1.0, 3.0], 0.5) == 2.0


def test_telemetry_counters_and_series():
    tel = Telemetry()
    tel.count("x")
    tel.count("x", 2)
    tel.observe("occ", 0.5)
    tel.observe("occ", 1.0)
    snap = tel.snapshot()
    assert snap["counters"]["x"] == 3
    assert snap["series"]["occ"]["n"] == 2
    assert snap["occupancy"] == 1.0            # no buckets yet
