"""Sharded serving: batch-axis shard_map executors, per-device fault
domains, mesh shrink-and-replan failover, and the scheduler's async
host loop + watchdog (ISSUE 7).

Device-mesh behavior (parity, dropout failover, total loss) runs on 4
fake host devices in a subprocess — ``XLA_FLAGS`` must be set before
jax imports.  The host-side machinery (DeviceHealth, ResultCache, the
watchdog, the async loop) is tested in-process against fake executors.
"""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.common.errors import (
    DeadlineExceeded, DeviceLostError, ExecutorError, KernelLaunchError,
    MeshExhausted, ReproError)
from repro.serving.scheduler import (
    ManualClock, MicroBatchScheduler, Request, ResultCache)
from repro.serving.sharding import DeviceHealth, ShardSpec, shard_width
from repro.serving.telemetry import Telemetry

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _run(body):
    import textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.common.errors import MeshExhausted
        from repro.core.efficientvit import B1_SMOKE, init_efficientvit
        from repro.core.quantization import quantize_efficientvit
        from repro.serving.executors import ExecutorCache
        from repro.serving.faults import FaultPlan, FaultSpec
        from repro.serving.scheduler import (
            ManualClock, MicroBatchScheduler, Request)
        from repro.serving.telemetry import Telemetry

        params = init_efficientvit(jax.random.PRNGKey(0), B1_SMOKE)

        def runtime(tree, precision="auto", faults=None, **kw):
            tel = Telemetry()
            clock = ManualClock()
            cache = ExecutorCache(tree, B1_SMOKE, buckets=(1, 2, 4),
                                  precision=precision, autotune=False,
                                  telemetry=tel, faults=faults,
                                  clock=clock, devices=jax.devices())
            sched = MicroBatchScheduler(cache, tree, telemetry=tel,
                                        clock=clock, faults=faults, **kw)
            return tel, cache, sched, clock

        def drain(sched, clock, rounds=64):
            for _ in range(rounds):
                if not sched.outstanding():
                    return
                sched.step(drain=True)
                sched.finalize()
                clock.advance(0.05)
            raise AssertionError("scheduler failed to drain")

        def images(n, seed=0):
            rng = np.random.default_rng(seed)
            return rng.standard_normal((n, 32, 32, 3)).astype(np.float32)
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# -- device-mesh behavior (subprocess, 4 fake devices) ---------------------

def test_sharded_matches_single_device():
    """One cache entry drives the whole mesh; fp parity to 1e-5 and
    int8 BIT-EXACT vs the single-device executor (per-batch-element
    activation scales make the batch split invisible)."""
    r = _run("""
        x = jnp.asarray(images(4))
        out = {}
        for name, tree, prec in (("fp", params, "auto"),
                                 ("int8", quantize_efficientvit(params),
                                  "int8")):
            tel = Telemetry()
            single = ExecutorCache(tree, B1_SMOKE, buckets=(4,),
                                   precision=prec, autotune=False,
                                   telemetry=tel)
            sharded = ExecutorCache(tree, B1_SMOKE, buckets=(4,),
                                    precision=prec, autotune=False,
                                    telemetry=Telemetry(),
                                    devices=jax.devices())
            ref = np.asarray(single.get(4, 32)(tree, x))
            ex = sharded.get(4, 32)
            got = np.asarray(ex(tree, x))
            out[name] = dict(
                maxdiff=float(np.max(np.abs(got - ref))),
                bitexact=bool(np.array_equal(got, ref)),
                local_batch=ex.shard.local_batch,
                device_ids=list(ex.device_ids))
        print(json.dumps(out))
    """)
    assert r["fp"]["maxdiff"] < 1e-5, r
    assert r["int8"]["bitexact"], r
    for prec in ("fp", "int8"):
        assert r[prec]["local_batch"] == 1
        assert r[prec]["device_ids"] == [0, 1, 2, 3]


def test_dropout_failover_completes_trace():
    """A device dies mid-trace: mesh shrinks 4->3, requests retry and
    complete on the survivors, the degradation ladder never moves, and
    the failed-over logits still match the healthy sharded executor."""
    r = _run("""
        faults = FaultPlan(FaultSpec("device.dropout", times=1, device=2))
        tel, cache, sched, clock = runtime(params, faults=faults,
                                           backoff_ms=0.0)
        imgs = images(4)
        reqs = [Request(rid=i, image=imgs[i]) for i in range(4)]
        for rq in reqs:
            sched.submit(rq)
        drain(sched, clock)
        healthy = ExecutorCache(params, B1_SMOKE, buckets=(4,),
                                autotune=False, telemetry=Telemetry(),
                                devices=jax.devices())
        ref = np.asarray(healthy.get(4, 32)(params, jnp.asarray(imgs)))
        got = np.stack([rq.logits for rq in reqs])
        print(json.dumps(dict(
            statuses=sorted({rq.status for rq in reqs}),
            retries=[rq.retries for rq in reqs],
            dead=list(cache.health.dead_ids()),
            epoch=cache.health.epoch,
            ladder=cache.degradation(4, 32) is not None,
            maxdiff=float(np.max(np.abs(got - ref))),
            counters={k: tel.counters[k] for k in
                      ("device_lost", "mesh_shrunk", "device_failover",
                       "retries") if k in tel.counters})))
    """)
    assert r["statuses"] == ["completed"], r
    assert r["dead"] == [2] and r["epoch"] == 1
    assert not r["ladder"], "device loss must not move the ladder"
    assert r["maxdiff"] < 1e-5, r
    assert r["counters"]["device_lost"] == 1
    assert r["counters"]["mesh_shrunk"] == 1
    assert r["retries"] == [1, 1, 1, 1]


def test_total_mesh_loss_fails_clean():
    """Every device dies: the trace terminates failed with typed
    MeshExhausted (no retry burn-down, no hang), and a late submit
    fails fast the same way."""
    r = _run("""
        faults = FaultPlan(*[FaultSpec("device.dropout", times=1, device=d)
                             for d in range(4)])
        tel, cache, sched, clock = runtime(params, faults=faults,
                                           backoff_ms=0.0)
        reqs = [Request(rid=i, image=img)
                for i, img in enumerate(images(4))]
        for rq in reqs:
            sched.submit(rq)
        drain(sched, clock)
        late = Request(rid=99, image=images(1, seed=3)[0])
        sched.submit(late)
        drain(sched, clock)
        print(json.dumps(dict(
            statuses=sorted({rq.status for rq in reqs}),
            typed=all(type(rq.error).__name__ == "MeshExhausted"
                      for rq in reqs + [late]),
            late_status=late.status,
            late_retries=late.retries,
            exhausted=cache.mesh_exhausted,
            outstanding=sched.outstanding())))
    """)
    assert r["statuses"] == ["failed"], r
    assert r["typed"] and r["exhausted"]
    assert r["late_status"] == "failed"
    assert r["late_retries"] <= 1, "exhausted mesh must not burn retries"
    assert r["outstanding"] == 0


# -- DeviceHealth / ShardSpec (host-only) ----------------------------------

class _Dev:
    def __init__(self, did):
        self.id = did

    def __repr__(self):
        return f"_Dev({self.id})"


def _health(n):
    return DeviceHealth(devices=tuple(_Dev(i) for i in range(n)))


def test_shard_width_picks_largest_divisor():
    assert shard_width(4, 4) == 4
    assert shard_width(4, 3) == 2     # 3 does not divide 4
    assert shard_width(4, 2) == 2
    assert shard_width(1, 4) == 1
    assert shard_width(2, 4) == 2     # never wider than the batch
    assert shard_width(6, 4) == 3
    with pytest.raises(ValueError):
        shard_width(0, 4)
    with pytest.raises(ValueError):
        shard_width(4, 0)


def test_device_health_shrink_and_exhaust():
    h = _health(4)
    assert h.n_alive == 4 and not h.exhausted and h.epoch == 0
    s = h.shard_for(4)
    assert isinstance(s, ShardSpec)
    assert s.device_ids == (0, 1, 2, 3) and s.local_batch == 1
    assert h.mark_dead(1)
    assert not h.mark_dead(1), "second report of the same death is a no-op"
    assert not h.mark_dead(77), "unknown device ids are ignored"
    assert h.epoch == 1 and h.dead_ids() == (1,)
    s = h.shard_for(4)
    assert s.device_ids == (0, 2) and s.local_batch == 2
    assert h.shard_for(1).device_ids == (0,)
    for d in (0, 2, 3):
        h.mark_dead(d)
    assert h.exhausted
    with pytest.raises(MeshExhausted):
        h.shard_for(4)


def test_device_health_attribution():
    h = _health(2)
    shard = h.shard_for(2)
    err = DeviceLostError("gone", device=1)
    assert h.attribute(err, shard) == 1
    # no device on the error: blame the shard's lead device
    assert h.attribute(KernelLaunchError("boom"), shard) == 0
    assert h.attribute(KernelLaunchError("boom"), None) is None


def test_error_taxonomy():
    assert issubclass(DeviceLostError, KernelLaunchError)
    assert issubclass(MeshExhausted, ExecutorError)
    assert DeviceLostError("x").transient, \
        "device loss is transient: the mesh shrinks and the request retries"
    assert not MeshExhausted("x").transient
    e = DeviceLostError("x", device=3)
    assert e.device == 3 and isinstance(e, ReproError)


def test_device_telemetry_row_attribution():
    tel = Telemetry()
    # bucket 4 over 2 devices, 3 real rows: dev0 holds rows 0-1 (real),
    # dev1 holds rows 2-3 (one real, one pad)
    tel.record_device_dispatch((0, 1), n_real=3, bucket_size=4)
    assert tel.devices[0].samples == 2 and tel.devices[0].padded == 0
    assert tel.devices[1].samples == 1 and tel.devices[1].padded == 1
    tel.record_device_error(1, lost=True)
    assert tel.devices[1].errors == 1 and tel.devices[1].lost
    snap = tel.snapshot()["devices"]
    assert snap[1]["lost"] and snap[0]["occupancy"] == 1.0
    assert "LOST" in tel.table()


# -- ResultCache (host-only) -----------------------------------------------

def test_result_cache_hit_miss_and_lru():
    rc = ResultCache(capacity=2)
    a = np.ones((4, 4, 3), np.float32)
    b = np.zeros((4, 4, 3), np.float32)
    c = np.full((4, 4, 3), 2.0, np.float32)
    assert rc.get(a) is None and rc.misses == 1
    assert rc.put(a, np.arange(4.0))
    np.testing.assert_array_equal(rc.get(a), np.arange(4.0))
    assert rc.hits == 1
    rc.put(b, np.arange(4.0) + 1)
    rc.put(c, np.arange(4.0) + 2)          # capacity 2: evicts a (LRU)
    assert rc.get(a) is None and len(rc) == 2
    # byte-identical content hits regardless of array identity
    assert rc.get(b.copy()) is not None


def test_result_cache_refuses_non_finite():
    rc = ResultCache()
    img = np.ones((4, 4, 3), np.float32)
    assert not rc.put(img, np.array([1.0, np.nan]))
    assert not rc.put(img, np.array([np.inf]))
    assert rc.get(img) is None and len(rc) == 0


# -- the scheduler against fake executors (host-only) ----------------------

class EchoExecutor:
    """Returns each row's mean — a per-request fingerprint, so ordering
    bugs surface as wrong logits, not just wrong counts."""

    def __init__(self, cache, bucket):
        self.cache, self.bucket = cache, bucket

    def __call__(self, params, x):
        if self.cache.call_faults:
            raise self.cache.call_faults.pop(0)
        x = np.asarray(x)
        return np.mean(x.reshape(x.shape[0], -1), axis=1,
                       keepdims=True).astype(np.float32)


class EchoCache:
    precision = "auto"

    def __init__(self, *, buckets=(1, 2, 4), call_faults=(), degraded=None):
        self.buckets = tuple(buckets)
        self.telemetry = Telemetry()
        self.call_faults = list(call_faults)
        self.degrades, self.pins = [], []
        self._degraded = degraded

    def get(self, batch, resolution):
        ex = EchoExecutor(self, batch)
        ex.degraded = self._degraded
        return ex

    def degrade(self, batch, resolution, *, site=None):
        self.degrades.append((batch, resolution, site))

    def pin_fp(self, batch, resolution):
        self.pins.append((batch, resolution))


def _fingerprint(img):
    return np.float32(np.mean(img))


def _reqs(n, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, image=rng.standard_normal(
        (8, 8, 3)).astype(np.float32), **kw) for i in range(n)]


def test_scheduler_result_cache_front_of_admission():
    cache = EchoCache()
    clock = ManualClock()
    sched = MicroBatchScheduler(cache, None, clock=clock, result_cache=8,
                                max_queue_depth=2)
    first = _reqs(2)
    for r in first:
        sched.submit(r)
    sched.step(drain=True)
    sched.finalize()
    assert all(r.status == "completed" for r in first)
    tel = cache.telemetry.counters
    assert tel["result_cache_miss"] == 2
    assert tel["result_cache_store"] == 2
    # byte-identical resubmission completes AT submit — in front of the
    # queue bound, which a fresh third image would trip
    again = [Request(rid=10 + i, image=first[i].image) for i in range(2)]
    for r in again:
        assert sched.submit(r)
        assert r.status == "completed"
    assert tel["result_cache_hit"] == 2
    np.testing.assert_allclose(
        np.ravel(again[0].logits), [_fingerprint(first[0].image)],
        rtol=1e-6)
    assert sched.queue_depth() == 0, "hits must not occupy queue slots"


def test_scheduler_degraded_results_never_cached():
    class Degraded:
        degraded = True
    cache = EchoCache(degraded=Degraded())
    sched = MicroBatchScheduler(cache, None, clock=ManualClock(),
                                result_cache=8)
    reqs = _reqs(2)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)
    sched.finalize()
    assert all(r.status == "completed" for r in reqs)
    assert len(sched.results) == 0, \
        "degraded executors' outputs must never enter the result cache"
    assert "result_cache_store" not in cache.telemetry.counters


def test_watchdog_converts_hung_batch():
    cache = EchoCache()
    clock = ManualClock()
    sched = MicroBatchScheduler(cache, None, clock=clock,
                                watchdog_ms=50.0, backoff_ms=0.0)
    reqs = _reqs(4)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)               # dispatched, now in flight
    assert sched.outstanding() == 4
    clock.advance(0.2)                   # blow the 50 ms in-flight bound
    sched.step()                         # watchdog sweeps before forming
    tel = cache.telemetry.counters
    assert tel["watchdog_fired"] == 1
    # DeadlineExceeded is persistent: the ladder moved immediately
    assert cache.degrades == [(4, 8, None)], cache.degrades
    assert all(r.retries == 1 for r in reqs)
    sched.step(drain=True)
    sched.finalize()
    assert all(r.status == "completed" for r in reqs)


def test_watchdog_spares_fresh_batches():
    cache = EchoCache()
    clock = ManualClock()
    sched = MicroBatchScheduler(cache, None, clock=clock, watchdog_ms=50.0)
    reqs = _reqs(4)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)
    clock.advance(0.01)                  # well inside the bound
    sched.finalize()
    assert all(r.status == "completed" for r in reqs)
    assert "watchdog_fired" not in cache.telemetry.counters


def test_async_loop_ordering_and_liveness():
    """The background host loop serves full buckets with no foreground
    step/finalize calls; each request gets ITS OWN image's fingerprint
    back (ordering), and wait() returns (liveness)."""
    cache = EchoCache()
    sched = MicroBatchScheduler(cache, None, clock=ManualClock())
    sched.start(poll_s=0.001)
    try:
        reqs = _reqs(8, seed=3)
        for r in reqs:
            sched.submit(r)
        assert sched.wait(reqs, timeout_s=30.0), \
            [(r.rid, r.status) for r in reqs]
        for r in reqs:
            np.testing.assert_allclose(np.ravel(r.logits),
                                       [_fingerprint(r.image)], rtol=1e-6)
    finally:
        sched.stop()
    assert not sched.running


def test_async_loop_stop_drains_tail():
    cache = EchoCache()
    sched = MicroBatchScheduler(cache, None, clock=ManualClock())
    sched.start(poll_s=0.001)
    reqs = _reqs(3, seed=4)              # never fills the 4-bucket, and
    for r in reqs:                       # the manual clock never makes
        sched.submit(r)                  # it due: only stop() drains it
    sched.stop(drain=True)
    assert all(r.status == "completed" for r in reqs)


def test_async_loop_concurrent_submitters():
    cache = EchoCache()
    sched = MicroBatchScheduler(cache, None, clock=ManualClock())
    sched.start(poll_s=0.001)
    groups = [_reqs(4, seed=10 + g) for g in range(4)]
    threads = [threading.Thread(
        target=lambda g=g: [sched.submit(r) for r in g]) for g in groups]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    flat = [r for g in groups for r in g]
    assert sched.wait(flat, timeout_s=30.0)
    sched.stop()
    for r in flat:
        np.testing.assert_allclose(np.ravel(r.logits),
                                   [_fingerprint(r.image)], rtol=1e-6)


def test_wait_times_out_without_loop():
    cache = EchoCache()
    sched = MicroBatchScheduler(cache, None, clock=ManualClock())
    r = _reqs(1)[0]
    sched.submit(r)
    t0 = time.monotonic()
    assert not sched.wait([r], timeout_s=0.1)
    assert time.monotonic() - t0 < 5.0


def test_device_lost_routes_to_failover_not_ladder():
    """A DeviceLostError from a fake executor calls the cache's
    on_device_lost hook and leaves degrade()/pin_fp() untouched."""
    class MeshCache(EchoCache):
        def __init__(self, **kw):
            super().__init__(**kw)
            self.lost = []
            self.mesh_exhausted = False

        def on_device_lost(self, device_id):
            self.lost.append(device_id)
            return True

    cache = MeshCache(call_faults=[DeviceLostError("dev gone", device=3)])
    clock = ManualClock()
    sched = MicroBatchScheduler(cache, None, clock=clock, backoff_ms=0.0)
    reqs = _reqs(4)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)               # dropout fires at dispatch
    assert cache.lost == [3]
    assert cache.degrades == [] and cache.pins == []
    sched.step(drain=True)
    sched.finalize()
    assert all(r.status == "completed" for r in reqs)
    assert cache.telemetry.counters["device_failover"] == 4


def test_mesh_exhausted_fails_without_retry_burn():
    cache = EchoCache()
    cache.mesh_exhausted = True

    def get(batch, resolution):
        raise MeshExhausted("all dead")
    cache.get = get
    sched = MicroBatchScheduler(cache, None, clock=ManualClock(),
                                backoff_ms=0.0)
    reqs = _reqs(4)
    for r in reqs:
        sched.submit(r)
    sched.step(drain=True)
    assert all(r.status == "failed" for r in reqs)
    assert all(isinstance(r.error, MeshExhausted) for r in reqs)
    assert all(r.retries <= 1 for r in reqs)
    assert sched.outstanding() == 0
    assert "retries" not in cache.telemetry.counters


def test_deadline_exceeded_from_watchdog_is_persistent():
    assert not DeadlineExceeded("hung").transient
