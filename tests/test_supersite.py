"""Inter-layer super-site fusion + single-load weight residency.

The contracts under test (ISSUE 10 / ROADMAP item 2):

  * ``SuperSite.of`` validates member chains at plan time (typed
    ``LoweringError``, never a shape error inside a jitted executor);
  * the grouping pass in ``plan_program`` collapses consecutive fused
    conv sites of one stage into one launch, and the grouped forward
    matches the site-by-site interpreter — fp to <1e-5, int8 BIT-EXACT;
  * weights are resident: one ``WeightPack`` per (param tree, precision,
    member chain), built once and shared across resolution buckets and
    executor rebuilds (``pack_stats`` / ``weight_pack_*`` telemetry),
    and the plan report counts each member's weight bytes exactly once
    with interior activation traffic at zero;
  * ``SiteOverride.group_break`` splits a chain exactly where pinned
    (the offline search's split/merge lever);
  * the fault ladder demotes a blamed member OUT of its group — the
    survivors regroup or run per-site, the key does not fall straight
    to the reference interpreter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.common.errors import LoweringError
from repro.core.efficientvit import EfficientViTConfig, init_efficientvit
from repro.core.fusion import (
    SiteOverride, launch_counts, plan_program, plan_report)
from repro.core.program import SuperSite, execute, lower
from repro.core.quantization import quantize_efficientvit
from repro.kernels.supersite.pack import (
    clear_pack_cache, get_pack, pack_stats, reset_pack_stats)
from repro.serving.executors import ExecutorCache

# Deep enough to form real chains (B1_SMOKE's depths of 1 group
# nothing): stem.ss0 = [stem.ds0, stem.ds1], S1.ss0 = [S1.mb0, S1.mb1],
# S2.ss0 = [S2.mb0, S2.mb1, S2.mb2].
CFG = EfficientViTConfig(name="ss-smoke", widths=(8, 16, 24, 32, 48),
                         depths=(2, 2, 3, 1, 1), head_widths=(64, 64),
                         num_classes=10, image_size=64)
N_GROUPS = 3


@pytest.fixture
def params():
    return init_efficientvit(jax.random.PRNGKey(0), CFG)


@pytest.fixture(autouse=True)
def fresh_pack_cache():
    clear_pack_cache()
    reset_pack_stats()
    yield
    clear_pack_cache()
    reset_pack_stats()


def _images(n, res=64, seed=1):
    return np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (n, res, res, 3)), np.float32)


def _groups(plan):
    return {g.name: tuple(g.members) for g in plan.groups.values()}


# ---------------------------------------------------------------------------
# SuperSite validation
# ---------------------------------------------------------------------------

def test_supersite_of_validates(params):
    program = lower(CFG, batch=1, image_size=64)
    sup = SuperSite.of(program, ("S2.mb0", "S2.mb1", "S2.mb2"))
    assert sup.stage == "S2" and len(sup.sites) == 3
    with pytest.raises(LoweringError):
        SuperSite.of(program, ("S2.mb0",))             # < 2 members
    with pytest.raises(LoweringError):
        SuperSite.of(program, ("S2.mb0", "S2.mb2"))    # not consecutive
    with pytest.raises(LoweringError):
        SuperSite.of(program, ("S1.mb1", "S2.mb0"))    # stage boundary


# ---------------------------------------------------------------------------
# grouping pass + chain parity vs the site-by-site interpreter
# ---------------------------------------------------------------------------

def test_grouping_pass_forms_expected_chains(params, tmp_autotune_cache):
    program = lower(CFG, batch=1, image_size=64)
    for tree in (params, quantize_efficientvit(params)):
        plan = plan_program(program, tree, autotune=False)
        assert _groups(plan) == {
            "stem.ss0": ("stem.ds0", "stem.ds1"),
            "S1.ss0": ("S1.mb0", "S1.mb1"),
            "S2.ss0": ("S2.mb0", "S2.mb1", "S2.mb2")}
        flat = plan_program(program, tree, autotune=False,
                            supersites=False)
        assert not flat.groups
        # each chain of k members collapses k launches into 1
        saved = sum(len(g.members) - 1 for g in plan.groups.values())
        assert launch_counts(flat)["fused"] \
            == launch_counts(plan)["fused"] + saved


def test_supersite_chain_parity_fp(params, tmp_autotune_cache):
    """Grouped vs site-by-site fused: <1e-5; both vs reference: close."""
    batch = 2
    program = lower(CFG, batch=batch, image_size=64)
    x = _images(batch)
    grouped = plan_program(program, params, autotune=False)
    flat = plan_program(program, params, autotune=False, supersites=False)
    assert grouped.groups and not flat.groups
    ref = execute(program, params, x)
    y_grouped = execute(program, params, x, plan=grouped)
    y_flat = execute(program, params, x, plan=flat)
    assert float(jnp.max(jnp.abs(y_grouped - y_flat))) < 1e-5
    assert_allclose(np.asarray(y_grouped), np.asarray(ref),
                    rtol=1e-3, atol=1e-3)


def test_supersite_chain_parity_int8_bit_exact(params, tmp_autotune_cache):
    """The grouped int8 chain is BIT-EXACT vs the site-by-site fused
    path: identical integer arithmetic, identical per-map quantization
    boundaries — the whole-map grid never re-quantizes mid-chain."""
    qparams = quantize_efficientvit(params)
    for batch in (1, 2):
        program = lower(CFG, batch=batch, image_size=64)
        x = _images(batch)
        grouped = plan_program(program, qparams, autotune=False)
        flat = plan_program(program, qparams, autotune=False,
                            supersites=False)
        assert all(g.precision == "int8" for g in grouped.groups.values())
        y_grouped = execute(program, qparams, x, plan=grouped)
        y_flat = execute(program, qparams, x, plan=flat)
        np.testing.assert_array_equal(np.asarray(y_grouped),
                                      np.asarray(y_flat))


# ---------------------------------------------------------------------------
# single-load weight residency
# ---------------------------------------------------------------------------

def test_weight_pack_built_once_counted_once(params, tmp_autotune_cache):
    program = lower(CFG, batch=1, image_size=64)
    plan = plan_program(program, params, autotune=False)
    g = plan.groups["S2.ss0"]
    sup = SuperSite.of(program, g.members, name=g.name)
    pack, hit = get_pack(params, sup, g.precision)
    assert not hit and pack_stats() == {"built": 1, "hits": 0}
    again, hit2 = get_pack(params, sup, g.precision)
    assert hit2 and again is pack                 # resident, not rebuilt
    assert pack_stats() == {"built": 1, "hits": 1}
    # the pack IS its flat buffers: every member weight appears once
    q_bytes = int(pack.q.size) if pack.q is not None else 0
    assert pack.nbytes == int(pack.fp.size) * 4 + q_bytes

    # report-level accounting: grouping never double-counts weight HBM,
    # and interior members deliver ZERO activation bytes
    flat_plan = plan_program(program, params, autotune=False,
                             supersites=False)
    rep, flat_rep = plan_report(plan), plan_report(flat_plan)
    assert sum(r["hbm_w"] for r in rep) \
        == sum(r["hbm_w"] for r in flat_rep)
    rows = {r["site"]: r for r in rep}
    for grp in plan.groups.values():
        for interior in grp.members[1:-1]:
            assert rows[interior]["hbm_delivered"] == 0, interior
        assert sum(rows[m]["launches_fused"] for m in grp.members) == 1


def test_bucket_switch_never_reuploads_weights(params, tmp_autotune_cache):
    """The pack cache keys on (param tree, precision, member chain) —
    NOT resolution — so a resolution-bucket switch re-hits every
    resident pack instead of re-uploading."""
    cache = ExecutorCache(params, CFG, buckets=(1, 2), autotune=False)
    cache.get(1, 64)
    t = cache.telemetry.counters
    assert t["weight_pack_built"] == N_GROUPS
    assert t.get("weight_pack_hit", 0) == 0
    cache.get(1, 32)                    # new resolution: fresh plan...
    assert t["weight_pack_built"] == N_GROUPS     # ...same packs
    assert t["weight_pack_hit"] == N_GROUPS
    cache.get(2, 64)                    # new bucket, same resolution
    assert t["weight_pack_built"] == N_GROUPS
    assert t["weight_pack_hit"] == 2 * N_GROUPS
    assert pack_stats()["built"] == N_GROUPS


# ---------------------------------------------------------------------------
# split/merge pins + the fault ladder
# ---------------------------------------------------------------------------

def test_group_break_override_splits_exactly_there(params,
                                                   tmp_autotune_cache):
    program = lower(CFG, batch=1, image_size=64)
    plan = plan_program(
        program, params, autotune=False,
        overrides={"S2.mb1": SiteOverride(group_break=True)})
    gs = _groups(plan)
    # the chain may not extend ACROSS S2.mb1: S2.mb0 is left alone
    # (a run of one groups nothing) and a new chain starts AT S2.mb1
    assert ("S2.mb1", "S2.mb2") in gs.values()
    assert not any("S2.mb0" in m for m in gs.values())
    assert gs["stem.ss0"] == ("stem.ds0", "stem.ds1")   # others intact
    assert gs["S1.ss0"] == ("S1.mb0", "S1.mb1")


def test_fault_demotion_splits_group_not_reference(params,
                                                   tmp_autotune_cache):
    """Blaming one member demotes THAT site (reason "fault") and the
    surviving members regroup — level 1 of the ladder, with a live
    fused plan, not a fall to the reference interpreter."""
    cache = ExecutorCache(params, CFG, buckets=(1,), autotune=False)
    healthy = cache.get(1, 64)
    assert "S2.ss0" in healthy.plan.groups
    state = cache.degrade(1, 64, site="S2.mb0")
    assert state.level == 1 and state.demoted == {"S2.mb0"}
    ex = cache.get(1, 64)
    assert ex.plan is not None                    # NOT the interpreter
    d = ex.plan.decisions["S2.mb0"]
    assert not d.fused and d.reason == "fault" and d.group == ""
    gs = _groups(ex.plan)
    assert gs["S2.ss0"] == ("S2.mb1", "S2.mb2")   # survivors regroup
    assert gs["S1.ss0"] == ("S1.mb0", "S1.mb1")
    # the degraded plan still serves correctly
    x = _images(1)
    program = lower(CFG, batch=1, image_size=64)
    ref = execute(program, params, x)
    out = execute(program, params, x, plan=ex.plan)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)
